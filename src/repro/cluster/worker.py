"""Worker-process side of the cluster runtime.

A worker is one OS process connected to the driver by a control-plane
*channel* (:mod:`repro.cluster.channel`): a duplex pipe for forked/spawned
in-host workers, or a framed TCP stream for workers dialed in from other
hosts.  The worker body below is channel-agnostic — it sees only blocking
``recv()``/``send()`` with :class:`~repro.cluster.channel.ChannelClosed`
as the "driver gone" signal.

It owns a *local object store* (``{tid: value}``) holding the results of
every task it has executed — plus, since the zero-copy data plane, a
replica of every transferred input it has resolved (reported back to the
driver in the ``done`` message so replica sets stay exact).  Bulk values do
not cross the control channel: a ``fetch`` is answered with a small
*handle* (:class:`~repro.cluster.serde.Encoded` shared-memory refs, or a
``PeerRef`` to this worker's unix/TCP socket server), and the consumer
maps/pulls the payload directly — worker-to-worker, driver untouched.

Message protocol (tuples; first element is the verb):

  driver -> worker
    ("run",   tid, extra)   execute task ``tid``; ``extra`` maps dep tid ->
                            transfer handle for inputs not already in this
                            worker's store
    ("fetch", tid)          publish ``tid`` and reply with its handle
    ("drop",  tids)         free stored values (driver-coordinated GC)
    ("cancel", tid)         a speculative twin of ``tid`` won elsewhere:
                            best-effort abort.  Idempotent — a queued run
                            of ``tid`` is skipped (acked ``cancelled``); a
                            run already executing completes and reports a
                            late ``done`` the driver reconciles; a tid
                            this worker never sees again is a no-op (the
                            mark is consumed by the next run or by the
                            task's own completion)
    ("hb",)                 keepalive (TCP channels; refreshes liveness)
    ("die",)                chaos hook: SIGKILL self (the driver cannot
                            signal a remote pid directly)
    ("stop",)               drain and exit

  worker -> driver
    ("done",    wid, tid, wall, nbytes, replicated)
                            task finished; value stays local.  ``nbytes``
                            feeds locality-aware placement; ``replicated``
                            lists dep tids this worker now also holds.
    ("error",   wid, tid, name, repr)    task raised; ``SerializationError``
                            means the *value* could not be published/moved —
                            surfaced as a task error, never a worker death
    ("value",   wid, tid, found, handle) fetch reply (handle, not payload)
    ("deplost", wid, tid, deps)          transfer handles in a ``run`` could
                            not be resolved (owner died mid-transfer);
                            driver re-queues the task and recovers the deps
    ("cancelled", wid, tid)              a queued run of ``tid`` was skipped
                            because a ``cancel`` (possibly stale) covered
                            it; the driver re-queues the task if it was
                            still wanted
    ("hb",)                              heartbeat (TCP channels)
    ("bye",     wid)                     explicit goodbye: clean shutdown,
                            never to be mistaken for a missed-heartbeat
                            death

Fork-started workers inherit the (closure-bearing, generally unpicklable)
:class:`~repro.core.graph.TaskGraph` and the run's ``inputs`` dict by
memory copy; spawn-started and remote TCP workers receive them pickled
(via process args or the handshake's welcome frame) — the paper's "ship
the program to every node" step either way, after which per-task messages
carry only ids and handles (a few hundred bytes, independent of payload
size).
"""
from __future__ import annotations

import os
import signal
from typing import Any, Dict, List, Optional

from repro.core.executor import _run_node as run_node   # noqa: F401 — the
# worker executes nodes with the EXACT core implementation so both backends
# share semantics (including the MissingInput contract; the driver re-raises
# it by name on its side)
from repro.core.graph import TaskGraph

from . import serde
from .channel import ChannelClosed, WorkerPipeEndpoint


def pipe_worker_main(wid: int, conn, graph: TaskGraph,
                     inputs: Optional[Dict[str, Any]],
                     transport: str = "driver",
                     shm_threshold: int = serde.SHM_THRESHOLD,
                     seg_prefix: str = "",
                     peer_dir: Optional[str] = None) -> None:
    """Process entrypoint for pipe/spawn channel workers: wrap the raw
    duplex-pipe connection in the channel-agnostic endpoint and run."""
    worker_main(wid, WorkerPipeEndpoint(conn), graph, inputs, transport,
                shm_threshold, seg_prefix, peer_dir)


def worker_main(wid: int, chan, graph: TaskGraph,
                inputs: Optional[Dict[str, Any]],
                transport: str = "driver",
                shm_threshold: int = serde.SHM_THRESHOLD,
                seg_prefix: str = "",
                peer_dir: Optional[str] = None,
                peer_host: str = "127.0.0.1") -> None:
    """Worker body: reader thread + sender thread + compute loop, over any
    control channel ``chan`` (blocking ``recv``/``send`` endpoint).

    Deadlock-freedom argument (handles are small, but driver-transport
    payloads can still exceed the kernel pipe/socket buffer): the reader
    thread does *nothing but recv*, so the driver's blocking
    dispatch-sends always drain; the sender thread does *nothing but send*
    from an outbox queue, so neither the reader nor a long-running task can
    ever stall an outgoing reply; the driver's pump loop drains worker
    output whenever it isn't mid-send.  Any single blocked channel
    therefore unblocks without waiting on this process's compute.

    The reader answers ``fetch``/``drop`` directly (peers' input transfers
    are served while a task is running); ``run``/``stop`` are queued for
    the compute loop.  ``store`` accesses are single-op (GIL-atomic) dict
    operations.
    """
    import queue
    import threading
    import time

    store: Dict[int, Any] = {}
    published: Dict[int, serde.Handle] = {}     # memoized publish per tid
    cancelled: set = set()      # tids whose next queued run is to be skipped
    # (set add/discard are GIL-atomic: reader marks, compute loop consumes)
    keeper = serde.SegmentKeeper()      # pins zero-copy decoded mappings
    runq: "queue.SimpleQueue[tuple]" = queue.SimpleQueue()
    outq: "queue.SimpleQueue[Optional[tuple]]" = queue.SimpleQueue()
    namer = serde.SegmentNamer(f"{seg_prefix}w{wid}") if seg_prefix else None

    peer_server: Optional[serde.PeerServer] = None
    if transport == "sock" and peer_dir:
        try:
            peer_server = serde.PeerServer(
                os.path.join(peer_dir, f"w{wid}.sock"), store)
        except OSError:
            peer_server = None      # degrade to inline (driver) publishes
    elif transport == "tcp":
        try:
            peer_server = serde.PeerServer(None, store,
                                           advertise_host=peer_host)
        except OSError:
            peer_server = None

    def publish(tid: int) -> serde.Handle:
        """Produce (and memoize) the transfer handle for a stored value:
        shm-backed Encoded, a PeerRef to this worker's socket server, or
        inline bytes for small values / driver transport."""
        handle = published.get(tid)
        if handle is not None:
            return handle
        value = store[tid]
        if (peer_server is not None
                and serde.payload_nbytes(value) >= shm_threshold):
            handle = serde.PeerRef(peer_server.path, tid,
                                   serde.payload_nbytes(value), wid,
                                   secret=peer_server.secret)
        else:
            handle = serde.encode(
                value, transport="driver" if transport in ("sock", "tcp")
                else transport, threshold=shm_threshold, namer=namer)
        published[tid] = handle
        return handle

    def sender() -> None:
        while True:
            msg = outq.get()
            if msg is None:
                return
            try:
                chan.send(msg)
            except ChannelClosed:
                return
            except Exception as e:      # unpicklable/oversized payload in a
                # reply: report it as a task error instead of wedging the
                # outbox (which would read as a dead worker to the driver)
                tid = msg[2] if len(msg) > 2 and isinstance(msg[2], int) \
                    else -1
                try:
                    chan.send(("error", wid, tid,
                               "SerializationError", repr(e)))
                except ChannelClosed:
                    return
                except Exception:
                    pass

    def reader() -> None:
        while True:
            try:
                msg = chan.recv()
            except ChannelClosed:
                runq.put(("stop",))      # driver went away
                return
            verb = msg[0]
            if verb == "fetch":
                tid = msg[1]
                if tid not in store:
                    outq.put(("value", wid, tid, False, None))
                else:
                    try:
                        outq.put(("value", wid, tid, True, publish(tid)))
                    except Exception as e:  # noqa: BLE001 — a value that
                        # cannot be serialized must surface on the consumer's
                        # future as a task error, not kill this worker
                        outq.put(("error", wid, tid,
                                  "SerializationError", repr(e)))
            elif verb == "drop":
                for t in msg[1]:
                    store.pop(t, None)
                    published.pop(t, None)
            elif verb == "cancel":
                # best-effort, between tasks: mark the tid; the compute
                # loop skips a queued run of it (a run already executing
                # finishes and the driver reconciles the late done)
                cancelled.add(msg[1])
            elif verb == "hb":
                pass                     # endpoint already refreshed liveness
            elif verb == "die":          # chaos hook for remote workers
                os.kill(os.getpid(), signal.SIGKILL)
            else:                        # "run" / "stop"
                runq.put(msg)
                if verb == "stop":
                    return

    send_thread = threading.Thread(target=sender, daemon=True,
                                   name=f"worker-{wid}-sender")
    send_thread.start()
    threading.Thread(target=reader, daemon=True,
                     name=f"worker-{wid}-reader").start()
    while True:
        msg = runq.get()
        verb = msg[0]
        if verb == "stop":
            if peer_server is not None:
                peer_server.close()
            outq.put(("bye", wid))
            outq.put(None)
            send_thread.join(timeout=5.0)
            keeper.close()       # last mappings: safe, nothing runs after
            chan.close()
            return
        if verb != "run":                # pragma: no cover — protocol bug
            raise RuntimeError(f"worker {wid}: unknown message {verb!r}")
        _, tid, extra = msg
        if tid in cancelled:
            # the winner already finished elsewhere; the mark is consumed
            # so a FUTURE legitimate dispatch of the same tid (lineage
            # recovery after a GC) runs normally — and the ack lets the
            # driver re-queue if this run was in fact still wanted
            cancelled.discard(tid)
            outq.put(("cancelled", wid, tid))
            continue
        t0 = time.perf_counter()
        try:
            table: Dict[int, Any] = {}
            lost: List[int] = []
            replicated: List[int] = []
            for d, handle in extra.items():
                try:        # zero-copy: arrays view the mapped segment
                    table[d] = serde.resolve(handle, keeper)
                except serde.TransferLost:
                    lost.append(d)
            if lost:
                # owner died (or GC raced) between dispatch and resolve:
                # hand the task back; the driver recovers the inputs
                outq.put(("deplost", wid, tid, lost))
                continue
            for d, v in table.items():   # keep transferred inputs: replicas
                store[d] = v
                published.pop(d, None)
                replicated.append(d)
            for d in graph.nodes[tid].all_deps:
                if d not in table:
                    table[d] = store[d]
            value = run_node(graph, tid, table, inputs)
            store[tid] = value
            published.pop(tid, None)     # recompute invalidates old handle
            # a cancel that raced the execution is moot now — consume the
            # mark so it cannot eat a future re-dispatch of this tid
            cancelled.discard(tid)
            outq.put(("done", wid, tid, time.perf_counter() - t0,
                      serde.payload_nbytes(value), replicated))
        except BaseException as e:       # noqa: BLE001 — shipped to driver
            cancelled.discard(tid)
            outq.put(("error", wid, tid, type(e).__name__, repr(e)))


def tcp_worker_main(address: str, *,
                    token: Optional[str] = None,
                    graph: Optional[TaskGraph] = None,
                    inputs: Optional[Dict[str, Any]] = None,
                    timeout: float = 30.0) -> int:
    """Process entrypoint for TCP-channel workers (local forked dialers and
    the ``repro-worker`` CLI alike): dial the driver at ``address``,
    handshake, and run :func:`worker_main` with the negotiated identity and
    run config.

    A worker launched with ``graph`` already in hand (forked locally, graph
    inherited) advertises ``has_graph=True`` and the driver skips shipping
    it; a bare remote worker receives the pickled ``(graph, inputs)`` pair
    in the welcome frame.  Returns the assigned worker id.
    """
    import pickle

    from .channel import dial_driver

    endpoint, wid, config, graph_blob = dial_driver(
        address, token=token, has_graph=graph is not None, timeout=timeout)
    if graph is None:
        if graph_blob is None:
            raise ChannelClosed(
                "driver sent no graph to a worker that has none")
        graph, inputs = pickle.loads(graph_blob)
    worker_main(wid, endpoint, graph, inputs,
                transport=config.get("transport", "driver"),
                shm_threshold=config.get("shm_threshold",
                                         serde.SHM_THRESHOLD),
                seg_prefix=config.get("seg_prefix", ""),
                peer_dir=config.get("peer_dir"),
                peer_host=config.get("peer_host", "127.0.0.1"))
    return wid
