"""Worker-process side of the cluster runtime.

A worker is one OS process connected to the driver by a single duplex pipe.
It owns a *local object store* (``{tid: value}``) holding the results of
every task it has executed and not yet dropped; values only cross the pipe
when the driver explicitly asks (dispatch-time transfer of remote inputs, or
an end-of-run / output fetch).  This is what makes worker loss *mean*
something: results that lived only in a killed worker's store are gone and
must be recomputed from lineage.

Message protocol (tuples; first element is the verb):

  driver -> worker
    ("run",   tid, extra)   execute task ``tid``; ``extra`` maps dep tid ->
                            value for inputs not in this worker's store
    ("fetch", tid)          reply with the stored value of ``tid``
    ("drop",  tids)         free stored values (driver-coordinated GC)
    ("stop",)               drain and exit

  worker -> driver
    ("done",  wid, tid, wall)          task finished; value stays local
    ("error", wid, tid, name, repr)    task raised
    ("value", wid, tid, found, value)  fetch reply
    ("bye",   wid)                     shutdown ack

Workers are started with the ``fork`` start method, so the (closure-bearing,
generally unpicklable) :class:`~repro.core.graph.TaskGraph` and the run's
``inputs`` dict are inherited by memory copy — the paper's "ship the program
to every node" step costs one fork, and per-task messages carry only ids and
data values (which must be picklable, as in any distributed system).
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.executor import _run_node as run_node   # noqa: F401 — the
# worker executes nodes with the EXACT core implementation so both backends
# share semantics (including the MissingInput contract; the driver re-raises
# it by name on its side)
from repro.core.graph import TaskGraph


def worker_main(wid: int, conn, graph: TaskGraph,
                inputs: Optional[Dict[str, Any]]) -> None:
    """Worker process body: reader thread + sender thread + compute loop.

    Deadlock-freedom argument (values can exceed the kernel pipe buffer):
    the reader thread does *nothing but recv*, so the driver's blocking
    dispatch-sends always drain; the sender thread does *nothing but send*
    from an outbox queue, so neither the reader nor a long-running task can
    ever stall an outgoing reply; the driver's pump loop drains worker
    output whenever it isn't mid-send.  Any single blocked pipe therefore
    unblocks without waiting on this process's compute.

    The reader answers ``fetch``/``drop`` directly (peers' input transfers
    are served while a task is running); ``run``/``stop`` are queued for
    the compute loop.  ``store`` accesses are single-op (GIL-atomic) dict
    operations.
    """
    import queue
    import threading
    import time

    store: Dict[int, Any] = {}
    runq: "queue.SimpleQueue[tuple]" = queue.SimpleQueue()
    outq: "queue.SimpleQueue[Optional[tuple]]" = queue.SimpleQueue()

    def sender() -> None:
        while True:
            msg = outq.get()
            if msg is None:
                return
            try:
                conn.send(msg)
            except (BrokenPipeError, OSError):
                return

    def reader() -> None:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                runq.put(("stop",))      # driver went away
                return
            verb = msg[0]
            if verb == "fetch":
                tid = msg[1]
                outq.put(("value", wid, tid, tid in store, store.get(tid)))
            elif verb == "drop":
                for t in msg[1]:
                    store.pop(t, None)
            else:                        # "run" / "stop"
                runq.put(msg)
                if verb == "stop":
                    return

    send_thread = threading.Thread(target=sender, daemon=True,
                                   name=f"worker-{wid}-sender")
    send_thread.start()
    threading.Thread(target=reader, daemon=True,
                     name=f"worker-{wid}-reader").start()
    while True:
        msg = runq.get()
        verb = msg[0]
        if verb == "stop":
            outq.put(("bye", wid))
            outq.put(None)
            send_thread.join(timeout=5.0)
            return
        if verb != "run":                # pragma: no cover — protocol bug
            raise RuntimeError(f"worker {wid}: unknown message {verb!r}")
        _, tid, extra = msg
        t0 = time.perf_counter()
        try:
            table = dict(extra)
            for d in graph.nodes[tid].all_deps:
                if d not in table:
                    table[d] = store[d]
            value = run_node(graph, tid, table, inputs)
            store[tid] = value
            outq.put(("done", wid, tid, time.perf_counter() - t0))
        except BaseException as e:       # noqa: BLE001 — shipped to driver
            outq.put(("error", wid, tid, type(e).__name__, repr(e)))
