"""repro.cluster — multi-process distributed runtime for TaskGraphs.

Backend choice (also see ROADMAP.md §runtime backends):

* ``thread`` (:class:`repro.core.executor.ThreadedExecutor`) — one process,
  work-stealing threads.  Zero serialization, shared memory; real speedups
  only when task payloads release the GIL (jitted JAX compute).  No fault
  isolation: a crashing task kills the run.
* ``process`` (:class:`ClusterExecutor`, here) — driver + forked OS-process
  workers over pipes.  True parallelism for Python-level work, per-worker
  object stores with driver-mediated transfer, and real fault tolerance:
  a SIGKILL'd worker triggers lineage recovery (recompute exactly the lost
  results) plus an elastic replan onto the survivors.  This is the template
  for the multi-host backend — swapping the fork+pipe transport for sockets
  changes no driver logic.

Both satisfy the :class:`repro.core.executor.Executor` protocol and are
differentially tested against ``execute_sequential`` (tasks are pure, so
every backend must agree bit-for-bit).

Public API: :class:`ClusterExecutor`, :class:`ClusterFuture`,
:func:`gather`, :class:`DriverObjectStore`.
"""
from .executor import ClusterExecutor
from .futures import ClusterFuture, gather
from .objectstore import DriverObjectStore

__all__ = ["ClusterExecutor", "ClusterFuture", "gather", "DriverObjectStore"]
