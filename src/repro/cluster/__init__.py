"""repro.cluster — multi-process distributed runtime for TaskGraphs.

Backend choice (also see ROADMAP.md §runtime backends):

* ``thread`` (:class:`repro.core.executor.ThreadedExecutor`) — one process,
  work-stealing threads.  Zero serialization, shared memory; real speedups
  only when task payloads release the GIL (jitted JAX compute).  No fault
  isolation: a crashing task kills the run.
* ``process`` (:class:`ClusterExecutor`, here) — driver + forked OS-process
  workers over pipes.  True parallelism for Python-level work, per-worker
  object stores, and real fault tolerance: a SIGKILL'd worker triggers
  lineage recovery (recompute exactly the lost results) plus an elastic
  replan onto the survivors.

The **data plane** is zero-copy (:mod:`repro.cluster.serde`): cross-worker
values move as handles — payload buffers are published once into
``multiprocessing.shared_memory`` segments (or pulled over a per-worker
unix socket when shm is unavailable) and mapped directly by the consumer,
so the driver pipe carries only control messages.  The
``transport={"auto","shm","sock","driver"}`` knob selects the channel
(``driver`` restores the PR-1 relay for A/B benchmarks), and the
``stats`` fields ``bytes_moved`` / ``bytes_driver`` / ``bytes_direct`` /
``transfers_direct`` / ``transfers_driver`` make the split observable.
Dispatch is **locality-aware**: per-value sizes recorded at completion
drive both the scheduler's comm-cost term and a transfer-cost score in the
driver's stealing loop, so consumers land on the worker already holding
the largest share of their input bytes — with per-host grouping, so a
same-host shm move is preferred over a cross-host TCP pull.

The **driver hot path is compiled, not interpreted**: a plan-time fusion
pass (:mod:`repro.core.fusion`, ``fuse={"off","auto",N}``) clusters the
task graph into super-tasks — one control message dispatches a whole
chain/fan-in/sibling group, members execute inside one worker frame, and
only cluster-boundary values touch the object store — while outgoing
control messages coalesce into per-worker batch frames
(``Channel.send_many``), amortizing pickle + syscall cost under load.
``stats`` exposes the win directly: ``n_clusters`` / ``tasks_fused`` /
``control_msgs`` / ``control_frames`` / ``dispatch_overhead_s``.  See
``docs/fusion.md``.

The **control plane** is an explicit channel layer
(:mod:`repro.cluster.channel`): the driver speaks the same tuple protocol
over forked duplex pipes (``channel="pipe"``), spawned fresh-interpreter
pipes (``"spawn"``), or a length-prefixed framed TCP stream (``"tcp"``)
that workers on any host dial into (``python -m repro.launch.remote
--connect <driver address>``).  TCP liveness is heartbeat-based — socket
death delivers no SIGCHLD — with an explicit goodbye distinguishing clean
shutdown from a crash, and sends ride backpressure-bounded queues so a
wedged peer reads as dead instead of wedging the driver.

Both executors satisfy the :class:`repro.core.executor.Executor` protocol
and are differentially tested against ``execute_sequential`` (tasks are
pure, so every backend must agree bit-for-bit), including under SIGKILL
mid-run and mid-transfer, over every channel and transport.

Public API: :class:`ClusterExecutor`, :class:`ClusterFuture`,
:func:`gather`, :class:`DriverObjectStore`, :mod:`repro.cluster.serde`,
:mod:`repro.cluster.channel`.
"""
from . import channel, serde
from .executor import ClusterExecutor, DriverKilled
from .futures import ClusterFuture, gather
from .objectstore import DriverObjectStore

__all__ = ["ClusterExecutor", "ClusterFuture", "gather", "DriverKilled",
           "DriverObjectStore", "serde", "channel"]
