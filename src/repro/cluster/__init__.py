"""repro.cluster — multi-process distributed runtime for TaskGraphs.

Backend choice (also see ROADMAP.md §runtime backends):

* ``thread`` (:class:`repro.core.executor.ThreadedExecutor`) — one process,
  work-stealing threads.  Zero serialization, shared memory; real speedups
  only when task payloads release the GIL (jitted JAX compute).  No fault
  isolation: a crashing task kills the run.
* ``process`` (:class:`ClusterExecutor`, here) — driver + forked OS-process
  workers over pipes.  True parallelism for Python-level work, per-worker
  object stores, and real fault tolerance: a SIGKILL'd worker triggers
  lineage recovery (recompute exactly the lost results) plus an elastic
  replan onto the survivors.

The **data plane** is zero-copy (:mod:`repro.cluster.serde`): cross-worker
values move as handles — payload buffers are published once into
``multiprocessing.shared_memory`` segments (or pulled over a per-worker
unix socket when shm is unavailable) and mapped directly by the consumer,
so the driver pipe carries only control messages.  The
``transport={"auto","shm","sock","driver"}`` knob selects the channel
(``driver`` restores the PR-1 relay for A/B benchmarks), and the
``stats`` fields ``bytes_moved`` / ``bytes_driver`` / ``bytes_direct`` /
``transfers_direct`` / ``transfers_driver`` make the split observable.
Dispatch is **locality-aware**: per-value sizes recorded at completion
drive both the scheduler's comm-cost term and a transfer-cost score in the
driver's stealing loop, so consumers land on the worker already holding
the largest share of their input bytes.  This is the template for the
multi-host backend — swapping the fork+pipe transport for sockets changes
no driver logic.

Both satisfy the :class:`repro.core.executor.Executor` protocol and are
differentially tested against ``execute_sequential`` (tasks are pure, so
every backend must agree bit-for-bit), including under SIGKILL mid-run and
mid-transfer.

Public API: :class:`ClusterExecutor`, :class:`ClusterFuture`,
:func:`gather`, :class:`DriverObjectStore`, :mod:`repro.cluster.serde`.
"""
from . import serde
from .executor import ClusterExecutor
from .futures import ClusterFuture, gather
from .objectstore import DriverObjectStore

__all__ = ["ClusterExecutor", "ClusterFuture", "gather",
           "DriverObjectStore", "serde"]
