"""Driver-side object store: replica tracking + handles + value cache + GC.

The driver does not hold every value — workers do (see
:mod:`repro.cluster.worker`).  What the driver tracks is *where* each
task's result lives, and since the zero-copy data plane a value can live in
several places at once:

* ``replicas[tid]`` — the set of workers holding the decoded value in
  their local stores (the producer, plus every consumer a transfer landed
  on).  A value is only *lost* when its **last** live replica dies and no
  durable copy exists — the post-transfer replica bug class the PR-1
  single-``owner`` field had.
* ``handles[tid]`` — the published transfer handle
  (:class:`~repro.cluster.serde.Encoded` or ``PeerRef``).  Shm/inline
  handles are **durable**: the payload lives in tmpfs or driver memory and
  survives the producing worker's death.  Peer handles die with their
  worker and are dropped in :meth:`drop_worker`.
* ``cache[tid]`` — values the driver has materialized (final collection);
  always durable.
* ``sizes[tid]`` — payload bytes reported at completion, feeding the
  locality-aware placement score in the executor's dispatch loop and the
  ``data_sizes`` comm-cost in :func:`repro.core.scheduler.list_schedule`.

Fault-tolerance contract (unchanged from PR-1 in spirit): a value with no
live replica, no durable handle, and no cached copy must be recomputed via
:func:`repro.core.lineage.recovery_plan`; a value dropped by GC is gone
*everywhere* and recovery walks past it.  The store is also the segment
refcount authority: :meth:`invalidate` releases a handle's shared-memory
segments, so the ``consumers_left`` GC unlinks ``/dev/shm`` entries the
moment the last consumer finishes.
"""
from __future__ import annotations

from typing import Any, Dict, Set

from repro.core.graph import TaskGraph

from . import serde


class DriverObjectStore:
    """Value-granular tracking, super-task-aware accounting.

    Since the fusion pass (``repro.core.fusion``) the driver dispatches
    *clusters* but values keep member-task identity: ``replicas`` /
    ``handles`` / ``cache`` / ``sizes`` are all keyed by member tid.  What
    changes with a non-identity ``plan`` is the **refcount universe**:
    intra-cluster reads happen inside one worker's execution frame and
    never touch the store, so ``consumers_left`` counts *consuming
    clusters* of each externally visible value — the identity plan makes
    that exactly the old per-task successor count.
    """

    def __init__(self, graph: TaskGraph, plan=None) -> None:
        if plan is None:
            from repro.core.fusion import identity_plan
            plan = identity_plan(graph)
        self.graph = graph
        self.plan = plan
        self.cache: Dict[int, Any] = {}          # driver-held decoded values
        self.replicas: Dict[int, Set[int]] = {}  # tid -> worker ids holding it
        self.handles: Dict[int, serde.Handle] = {}   # tid -> published handle
        self.sizes: Dict[int, int] = {}          # tid -> payload bytes
        self.known: Dict[int, Set[int]] = {}     # worker id -> {tid} it holds
        self.worker_host: Dict[int, Any] = {}    # worker id -> machine id
        self.dropped: Set[int] = set()           # tids swept by the GC
        self.successors = graph.successors()
        self.consumers_left: Dict[int, int] = {
            tid: len(plan.consumers.get(tid, ())) for tid in graph.nodes}

    # ------------------------------------------------------------ admission
    def admit(self, tids) -> None:
        """A resident-mode job was admitted: extend the refcount universe
        to its member tids.  ``self.plan``/``self.graph`` are the live
        (already merged) union objects, so the consumer counts come from
        the same source the initial constructor snapshot did.  Existing
        entries are never touched — earlier jobs' in-flight refcounts must
        not be reset by a newcomer."""
        for tid in tids:
            if tid not in self.consumers_left:
                self.consumers_left[tid] = \
                    len(self.plan.consumers.get(tid, ()))

    def retire(self, tids) -> None:
        """A resident-mode job was collected (or failed): drop its values
        everywhere and forget its refcounts, so a long-lived gateway run's
        store does not grow with every job ever submitted."""
        self.invalidate(set(tids))
        for tid in tids:
            self.consumers_left.pop(tid, None)
            self.sizes.pop(tid, None)
            self.dropped.discard(tid)

    # ------------------------------------------------------------ ownership
    def add_worker(self, wid: int, host: Any = "local") -> None:
        self.known.setdefault(wid, set())
        self.worker_host[wid] = host

    def on_host(self, tid: int, host: Any) -> bool:
        """True when some replica of ``tid`` lives on machine ``host`` —
        the per-host locality grouping: a same-host copy is reachable over
        shm/unix-socket (near), a cross-host one only over TCP (far)."""
        return any(self.worker_host.get(w) == host
                   for w in self.replicas.get(tid, ()))

    def record(self, tid: int, wid: int, nbytes: int = 0) -> None:
        """Task ``tid`` completed on worker ``wid``; value lives there."""
        self.replicas.setdefault(tid, set()).add(wid)
        self.known.setdefault(wid, set()).add(tid)
        if nbytes:
            self.sizes[tid] = nbytes

    def record_replica(self, tid: int, wid: int) -> None:
        """A transfer landed the (pure, hence identical) value of ``tid``
        in ``wid``'s local store too — a real copy, usable for future
        locality and surviving the original owner's death."""
        self.replicas.setdefault(tid, set()).add(wid)
        self.known.setdefault(wid, set()).add(tid)

    def has_replica(self, tid: int, wid: int) -> bool:
        return wid in self.replicas.get(tid, ())

    def locations(self, tid: int) -> Set[int]:
        return self.replicas.get(tid, set())

    def set_handle(self, tid: int, handle: serde.Handle) -> None:
        old = self.handles.get(tid)
        if old is not None and old is not handle:
            serde.release(old)
        self.handles[tid] = handle

    def durable(self, tid: int) -> bool:
        h = self.handles.get(tid)
        return tid in self.cache or (h is not None and serde.is_durable(h))

    def cache_value(self, tid: int, value: Any) -> None:
        self.cache[tid] = value

    def available(self, alive: Set[int]) -> Set[int]:
        """Tids whose values still exist somewhere: driver cache, a durable
        published handle (tmpfs / driver memory), or a live replica."""
        out = set(self.cache)
        out |= {t for t, h in self.handles.items() if serde.is_durable(h)}
        for wid in alive:
            out |= self.known.get(wid, set())
        return out

    # --------------------------------------------------------------- resume
    def seed_after_outage(self, done_clusters: Set[int],
                          inventories: Dict[int, Any],
                          handles: Dict[int, serde.Handle],
                          values: Dict[int, Any],
                          dropped: Set[int]) -> None:
        """Rebuild a fresh store from a checkpoint plus rejoined-worker
        inventory after a driver outage.  ``inventories`` maps worker id
        (already registered via :meth:`add_worker`) to ``(tid, nbytes)``
        pairs the worker still holds; ``handles``/``values`` are the
        durable copies the run log recorded (existence-verified by the
        caller); ``dropped`` is the GC frontier the log claims.  Inventory
        wins over a ``dropped`` claim — a worker that still holds a value
        makes it live again (worst case the refcount GC re-sweeps it).
        Handles are assigned directly, never through :meth:`set_handle`:
        there is no prior handle to release in a store this young, and a
        release here would unlink the very tmpfs segment that survived
        the outage."""
        inv_tids: Set[int] = set()
        for wid, inv in inventories.items():
            for tid, nbytes in inv:
                inv_tids.add(tid)
                if self.plan.cluster_of.get(tid) in done_clusters:
                    self.record(tid, wid, nbytes)
        self.handles.update(handles)
        self.cache.update(values)
        self.dropped = set(dropped) - inv_tids - set(self.cache) \
            - set(self.handles)
        # refcount universe: consumers that already completed never re-read
        self.consumers_left = {
            tid: sum(1 for c in self.plan.consumers.get(tid, ())
                     if c not in done_clusters)
            for tid in self.graph.nodes}

    # -------------------------------------------------------------- failure
    def drop_worker(self, wid: int) -> Set[int]:
        """Worker died: forget its store.  Returns the tids whose values are
        now *lost* — no surviving replica AND no durable copy.  A value
        replicated by an earlier transfer, published to shared memory, or
        cached on the driver is NOT lost (the replica-set fix: PR-1's single
        ``owner`` field reported any multiply-held value as lost)."""
        held = self.known.pop(wid, set())
        self.worker_host.pop(wid, None)
        lost: Set[int] = set()
        for t in held:
            reps = self.replicas.get(t)
            if reps is not None:
                reps.discard(wid)
                if not reps:
                    del self.replicas[t]
            h = self.handles.get(t)
            if isinstance(h, serde.PeerRef) and h.wid == wid:
                del self.handles[t]          # peer handle died with it
            elif isinstance(h, serde.DualRef) and h.peer.wid == wid:
                # the TCP half died with the worker and the shm half is
                # host-scoped (unreachable from other machines), so the
                # handle goes.  The release only reaches segments on the
                # DRIVER's host (a same-host worker's crash); a remote
                # crash leaves its segments to that host's own hygiene —
                # the documented repro-worker-sweep open item
                serde.release(h)
                del self.handles[t]
            if not self.replicas.get(t) and not self.durable(t):
                lost.add(t)
        return lost

    def invalidate(self, tids: Set[int]) -> None:
        """Remove every trace of ``tids`` (they will be recomputed or have
        been GC'd), and unlink any shared-memory segments their handles
        held.  Clears any GC ``dropped`` mark: a recomputed value is live
        again (the ``mark_dropped`` caller re-marks after a GC sweep)."""
        for t in tids:
            self.cache.pop(t, None)
            self.dropped.discard(t)
            serde.release(self.handles.pop(t, None))
            for wid in self.replicas.pop(t, set()):
                self.known.get(wid, set()).discard(t)

    # -------------------------------------------------- duplicate publishes
    def mark_dropped(self, tid: int) -> None:
        """The ``consumers_left`` GC swept ``tid`` everywhere.  A *late*
        duplicate publish of it (a speculation loser finishing after the
        winner AND after the sweep) must be swept too, not resurrected as
        a replica — :meth:`was_dropped` is how the executor tells the two
        apart when the late ``done`` arrives."""
        self.dropped.add(tid)

    def was_dropped(self, tid: int) -> bool:
        return tid in self.dropped

    def release_all(self) -> None:
        """End of run: free every outstanding handle's segments."""
        for h in self.handles.values():
            serde.release(h)
        self.handles.clear()

    # ------------------------------------------------------------------- GC
    def consumed(self, tid: int) -> None:
        """A consumer of ``tid`` completed."""
        if tid in self.consumers_left:
            self.consumers_left[tid] -= 1

    def collectable(self, tid: int) -> bool:
        return (self.consumers_left.get(tid, 1) <= 0
                and tid not in self.graph.outputs)

    def reset_consumers(self, recomputed: Set[int],
                        will_run: Set[int]) -> None:
        """After scheduling a recovery plan (``recomputed`` cluster ids),
        a recomputed cluster's externally visible values are needed once
        per consuming cluster that will still execute (``will_run`` =
        recovery plan ∪ not-yet-done clusters; consumers that stayed
        completed never re-read).  External inputs a re-run cluster will
        read — values that stayed available outside the plan — gain one
        pending read each, so the GC cannot sweep them out from under the
        recovery."""
        plan = self.plan
        for c in recomputed:
            for v in plan.outputs[c]:
                self.consumers_left[v] = sum(
                    1 for cc in plan.consumers.get(v, ())
                    if cc in will_run)
            for v in plan.ext_deps[c]:
                if plan.cluster_of[v] not in recomputed:
                    self.consumers_left[v] = \
                        self.consumers_left.get(v, 0) + 1
