"""Driver-side object store: ownership tracking + value cache + GC.

The driver does not hold every value — workers do (see
:mod:`repro.cluster.worker`).  What the driver tracks is *where* each task's
result lives (``owner``), which values it has pulled into its own durable
cache (``cache``), and how many consumers still need each value
(``consumers_left``, driving the optional distributed GC in
``outputs_only`` runs).

This split is what gives the fault-tolerance story its teeth:

* a value in ``cache`` survives any worker death (driver memory is the
  durable tier here; a sharded/replicated store is the scale-out follow-up);
* a value known only to a dead worker is **lost** and must be recomputed
  via :func:`repro.core.lineage.recovery_plan`;
* a value dropped by GC is gone *everywhere* — recovery for a later loss
  walks past it and recomputes it too, exactly the Spark-lineage semantics
  the paper points at.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Set

from repro.core.graph import TaskGraph


class DriverObjectStore:
    def __init__(self, graph: TaskGraph) -> None:
        self.graph = graph
        self.cache: Dict[int, Any] = {}         # driver-held values
        self.owner: Dict[int, int] = {}         # tid -> worker id
        self.owned: Dict[int, Set[int]] = {}    # worker id -> {tid}
        succ = graph.successors()
        self.successors = succ
        self.consumers_left: Dict[int, int] = {
            tid: len(succ[tid]) for tid in graph.nodes}

    # ------------------------------------------------------------ ownership
    def add_worker(self, wid: int) -> None:
        self.owned.setdefault(wid, set())

    def record(self, tid: int, wid: int) -> None:
        """Task ``tid`` completed on worker ``wid``; value lives there."""
        self.owner[tid] = wid
        self.owned.setdefault(wid, set()).add(tid)

    def cache_value(self, tid: int, value: Any) -> None:
        self.cache[tid] = value

    def location(self, tid: int) -> Optional[int]:
        return self.owner.get(tid)

    def available(self, alive: Set[int]) -> Set[int]:
        """Tids whose values still exist somewhere (driver or live worker)."""
        out = set(self.cache)
        for wid in alive:
            out |= self.owned.get(wid, set())
        return out

    # -------------------------------------------------------------- failure
    def drop_worker(self, wid: int) -> Set[int]:
        """Worker died: forget its store.  Returns the tids whose values are
        now *lost* (they lived only there — not in the driver cache)."""
        held = self.owned.pop(wid, set())
        lost = {t for t in held if t not in self.cache}
        for t in held:
            if self.owner.get(t) == wid:
                del self.owner[t]
        return lost

    def invalidate(self, tids: Set[int]) -> None:
        """Remove every trace of ``tids`` (they will be recomputed)."""
        for t in tids:
            self.cache.pop(t, None)
            w = self.owner.pop(t, None)
            if w is not None:
                self.owned.get(w, set()).discard(t)

    # ------------------------------------------------------------------- GC
    def consumed(self, tid: int) -> None:
        """A consumer of ``tid`` completed."""
        if tid in self.consumers_left:
            self.consumers_left[tid] -= 1

    def collectable(self, tid: int) -> bool:
        return (self.consumers_left.get(tid, 1) <= 0
                and tid not in self.graph.outputs)

    def reset_consumers(self, plan: Set[int], will_run: Set[int]) -> None:
        """After scheduling a recovery ``plan``, a recomputed task's value is
        needed once per consumer that will still execute: plan members being
        recomputed AND successors that never ran in the first place
        (``will_run`` = plan ∪ not-yet-done).  Consumers that stayed
        completed never re-read it."""
        for t in plan:
            self.consumers_left[t] = sum(
                1 for s in self.successors[t] if s in will_run)
