"""Transport-agnostic control-plane channels for the cluster runtime.

The driver logic in :mod:`repro.cluster.executor` was always
transport-agnostic *in spirit* — it exchanges small tuple messages with
workers and never cares how they travel.  This module makes it so *in
code*: the executor talks to every worker through a :class:`Channel`, and
three implementations cover the deployment shapes the paper's
"large clusters" claim needs:

* :class:`PipeChannel` — today's fork+pipe path, kept as the in-host fast
  path.  One ``multiprocessing`` duplex pipe per forked worker; liveness
  is the OS truth (``proc.is_alive()`` — a SIGKILL is visible instantly).
* :class:`SpawnChannel` — the same pipe wiring for ``spawn``/``forkserver``
  workers (fresh interpreters; the graph must be picklable).  Kept as a
  distinct class because the *launch* contract differs (ship the recipe,
  not the memory image), not the wire format.
* :class:`TcpChannel` — a length-prefixed, message-framed TCP stream.
  This is the multi-host channel: a worker on any machine dials the
  driver's :class:`TcpListener`, handshakes (magic + protocol version +
  optional shared token + host identity), and then speaks the exact same
  tuple protocol.  Because a remote peer's death does not deliver SIGCHLD,
  liveness is **heartbeat-based**: both sides emit ``("hb",)`` frames on an
  interval, every received frame refreshes the peer's ``last_seen``, and
  :meth:`TcpChannel.dead` reports a peer silent past ``heartbeat_timeout``
  (an explicit ``("bye", wid)`` goodbye marks a *clean* exit so shutdown
  is never mistaken for a crash).  Sends go through a **bounded outbox**
  drained by a sender thread — backpressure: a peer that stops reading
  fills the queue and the send fails as a dead-peer event instead of
  wedging the driver loop on a blocking ``sendall``.

Driver-side contract (what the executor's event loop needs):

  ``selectable()``       object for ``multiprocessing.connection.wait``
  ``send(msg)``          enqueue/write one message; ``ChannelClosed`` if the
                         peer is gone (the caller turns that into a death)
  ``send_many(msgs)``    coalesce a burst of messages into one wire write
                         (a ``("batch", [...])`` frame: one pickle + one
                         syscall); order preserved, peers unwrap — the
                         driver flushes its per-worker outbox through this
                         once per event-loop iteration
  ``recv_available()``   drain every complete message currently readable
                         (never blocks after ``wait`` reported readability);
                         ``ChannelClosed`` on EOF
  ``dead()``             liveness verdict: ``None`` while believed alive,
                         else a human-readable reason
  ``maybe_heartbeat()``  rate-limited keepalive (no-op for pipes)
  ``close()``            release the endpoint

Worker-side endpoints (:class:`WorkerPipeEndpoint`,
:class:`WorkerTcpEndpoint`) expose blocking ``recv()`` + ``send()`` with
the same ``ChannelClosed`` error surface, so
:func:`repro.cluster.worker.worker_main` runs unchanged over any wire.

Every channel carries the same verb-tuple protocol (the table lives in
:mod:`repro.cluster.worker`), including the idempotent ``("cancel", tid)``
/ ``("cancelled", wid, tid)`` pair speculation uses to abort a losing
duplicate between tasks — pipe and TCP alike, no per-wire special case.
"""
from __future__ import annotations

import os
import pickle
import queue
import random
import socket
import struct
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

PROTOCOL_MAGIC = "repro-cluster"
# v2: super-task dispatch (``run``/``done`` carry cluster ids and
# per-member size maps) + ``("batch", [msgs])`` coalesced frames
# v3: driver-restart re-adoption — the hello may carry ``rejoin``/``wid``
# (a surviving worker re-dialing a resumed run), and a rejoining worker's
# first post-welcome frame is its ``("inv", wid, [(tid, nbytes), ...])``
# object-store inventory
PROTOCOL_VERSION = 3

#: control-plane channels a ClusterExecutor can be built on (the
#: transport matrix lives in serde.TRANSPORTS / serde.CROSS_HOST_TRANSPORTS)
CHANNELS = ("pipe", "spawn", "tcp")

_FRAME = struct.Struct("<Q")         # length prefix, host-order-independent
_MAX_FRAME = 1 << 34                 # 16 GiB sanity bound on one message


class ChannelClosed(ConnectionError):
    """The peer is unreachable (EOF, reset, dead process, backpressure
    overflow).  The executor treats this exactly like a worker death."""


class DialRejected(ChannelClosed):
    """The driver answered the dial and said no (bad token, wrong
    protocol, unshippable graph).  A *definitive* refusal — retrying the
    same dial cannot succeed, so retry policies must let it propagate."""


_SILENCE_PREFIX = "no heartbeat"


def is_silence(reason: Optional[str]) -> bool:
    """Classify a ``Channel.dead()`` verdict: silence-based verdicts
    (missed heartbeats — the peer may be partitioned-but-alive) are
    *suspicions* the executor grants a grace window; everything else
    (process exit, EOF, send failure) is definitive death."""
    return bool(reason) and reason.startswith(_SILENCE_PREFIX)


def wrap_batch(msgs: List[tuple]) -> Optional[tuple]:
    """The batch envelope, in one place: a single message travels bare, a
    burst travels as one ``("batch", [...])`` frame (one pickle + one
    syscall).  Returns ``None`` for an empty burst.  Every sender — both
    channel families and the worker's reply thread — must wrap through
    here so the envelope can never diverge from :func:`_flatten_batches`.
    """
    if not msgs:
        return None
    if len(msgs) == 1:
        return msgs[0]
    return ("batch", list(msgs))


def _flatten_batches(msgs: List[tuple]) -> List[tuple]:
    """Unwrap ``("batch", [...])`` frames into their member messages, in
    order.  Batching is a *wire* optimization (one pickle + one syscall
    for a burst of control messages); no consumer above the channel layer
    ever sees a batch frame."""
    if not any(m and m[0] == "batch" for m in msgs):
        return msgs
    flat: List[tuple] = []
    for m in msgs:
        if m and m[0] == "batch":
            flat.extend(m[1])
        else:
            flat.append(m)
    return flat


def host_id() -> str:
    """Identity of this machine for per-host locality grouping and the
    cross-host transport guard.  Hostname alone collides across cloned
    VMs / default cloud images, so it is salted with the stable
    machine-id when one exists — every process on one machine must agree
    on the id, so no per-process randomness is allowed here."""
    name = socket.gethostname() or "localhost"
    try:
        with open("/etc/machine-id") as f:
            mid = f.read().strip()[:12]
        if mid:
            return f"{name}-{mid}"
    except OSError:
        pass
    return name


def routable_ip() -> str:
    """Best-effort non-loopback IP of this machine.  Used as the peer
    data-plane advertise address for *local* workers in a mixed
    local+remote pool: they dial the driver over loopback, but a remote
    consumer pulling from their PeerServer must reach this machine's real
    interface, not 127.0.0.1 on its own."""
    try:
        s = socket.socket(socket.AF_INET, socket.SOCK_DGRAM)
        try:        # routing-table lookup only; no packet is sent
            s.connect(("10.254.254.254", 1))
            ip = s.getsockname()[0]
        finally:
            s.close()
        if not ip.startswith("127."):
            return ip
    except OSError:
        pass
    try:
        ip = socket.gethostbyname(socket.gethostname())
        if not ip.startswith("127."):
            return ip
    except OSError:
        pass
    return "127.0.0.1"


# --------------------------------------------------------------------- pipe
class PipeChannel:
    """Driver-side endpoint of a forked worker's duplex pipe.

    Liveness is authoritative: the worker is a child process, so
    ``proc.is_alive()`` sees SIGKILL/OOM the moment the OS reaps it —
    no heartbeats needed on this channel.
    """

    kind = "pipe"

    def __init__(self, conn, proc) -> None:
        self.conn = conn
        self.proc = proc
        self._closed = False

    def selectable(self):
        return self.conn

    def send(self, msg: tuple) -> None:
        # NOTE: ValueError (an over-2GiB pipe message) deliberately
        # propagates — it is a caller bug, not a dead worker, and mapping
        # it to ChannelClosed would cascade fake deaths across the pool
        try:
            self.conn.send(msg)
        except (BrokenPipeError, OSError) as e:
            raise ChannelClosed(f"pipe send failed: {e!r}") from e

    def send_many(self, msgs: List[tuple]) -> None:
        """Coalesce a burst of messages into one wire write (one pickle +
        one syscall) — the driver's per-iteration outbox flush.  Order is
        preserved; the worker-side reader unwraps the batch frame."""
        wrapped = wrap_batch(msgs)
        if wrapped is not None:
            self.send(wrapped)

    def recv_available(self) -> List[tuple]:
        # mp pipes deliver whole messages; one recv per readability event
        # matches the pre-channel driver loop exactly
        try:
            return _flatten_batches([self.conn.recv()])
        except (EOFError, OSError) as e:
            raise ChannelClosed(f"pipe EOF: {e!r}") from e

    def dead(self) -> Optional[str]:
        if self.proc is not None and not self.proc.is_alive():
            return f"process exited (code {self.proc.exitcode})"
        return None

    def maybe_heartbeat(self) -> None:     # pipes don't need keepalives
        pass

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self.conn.close()
        except OSError:
            pass


class SpawnChannel(PipeChannel):
    """Pipe wiring for ``spawn``/``forkserver`` workers.  Wire-identical to
    :class:`PipeChannel`; the difference is the launch contract (the child
    is a fresh interpreter, so the graph crossed by pickling, exactly like
    a remote worker receives it over TCP)."""

    kind = "spawn"


class WorkerPipeEndpoint:
    """Worker-side face of a duplex pipe, matching the TCP endpoint API."""

    def __init__(self, conn) -> None:
        self.conn = conn

    def recv(self) -> tuple:
        try:
            return self.conn.recv()
        except (EOFError, OSError) as e:
            raise ChannelClosed(f"driver gone: {e!r}") from e

    def send(self, msg: tuple) -> None:
        try:
            self.conn.send(msg)
        except (BrokenPipeError, OSError) as e:
            raise ChannelClosed(f"driver gone: {e!r}") from e

    def close(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass


# ---------------------------------------------------------------- tcp frames
def _send_frame(sock: socket.socket, payload: bytes,
                lock: Optional[threading.Lock] = None) -> None:
    data = _FRAME.pack(len(payload)) + payload
    if lock is None:
        sock.sendall(data)
    else:
        with lock:
            sock.sendall(data)


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        chunk = sock.recv(min(n, 1 << 20))
        if not chunk:
            raise ChannelClosed("peer closed mid-frame")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_raw_frame(sock: socket.socket, max_len: int = _MAX_FRAME) -> bytes:
    (n,) = _FRAME.unpack(_recv_exact(sock, _FRAME.size))
    if not 0 <= n <= max_len:
        raise ChannelClosed(f"insane frame length {n}")
    return _recv_exact(sock, n)


def _recv_frame(sock: socket.socket) -> tuple:
    return pickle.loads(_recv_raw_frame(sock))


class _FrameBuffer:
    """Incremental parser for length-prefixed frames (driver side, where
    reads happen in non-blocking bites after ``wait`` reports data)."""

    def __init__(self) -> None:
        self._buf = bytearray()

    def feed(self, data: bytes) -> List[tuple]:
        self._buf.extend(data)
        msgs: List[tuple] = []
        while True:
            if len(self._buf) < _FRAME.size:
                return msgs
            (n,) = _FRAME.unpack_from(self._buf)
            if not 0 <= n <= _MAX_FRAME:
                raise ChannelClosed(f"insane frame length {n}")
            end = _FRAME.size + n
            if len(self._buf) < end:
                return msgs
            msgs.append(pickle.loads(bytes(self._buf[_FRAME.size:end])))
            del self._buf[:end]


# ----------------------------------------------------------------- tcp chan
class TcpChannel:
    """Driver-side endpoint of one dialed-in worker.

    * **Framing** — ``<u64 len><pickle>`` per message; a partial read parks
      bytes in a :class:`_FrameBuffer` until the frame completes.
    * **Liveness** — every received frame (heartbeats included) refreshes
      ``last_seen``; :meth:`dead` trips after ``heartbeat_timeout`` of
      silence.  A clean ``bye`` sets :attr:`said_goodbye` so shutdown
      drains are not misread as crashes.  EOF/reset surface as
      :class:`ChannelClosed` from :meth:`recv_available`.
    * **Backpressure** — :meth:`send` enqueues into a bounded outbox; a
      dedicated sender thread owns the socket's write side.  A peer that
      stops draining fills the queue and the next send raises
      :class:`ChannelClosed` after ``send_timeout`` instead of blocking
      the driver loop forever.
    """

    kind = "tcp"

    def __init__(self, sock: socket.socket, *,
                 heartbeat_interval: float = 2.0,
                 heartbeat_timeout: float = 10.0,
                 heartbeat_jitter: float = 0.25,
                 outbox_size: int = 256,
                 send_timeout: float = 30.0,
                 proc=None) -> None:
        self.sock = sock
        self.proc = proc            # local dialer's process, if any (chaos
        # hooks use it; liveness does NOT — multi-host has no proc to ask)
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        # per-channel jittered beat: each peer's keepalives land at
        # interval*(1-jitter)..interval, so a large pool's heartbeats
        # de-phase instead of arriving as one synchronized burst (always
        # early, never late — timeout margins are unchanged)
        self.heartbeat_jitter = max(0.0, min(0.9, heartbeat_jitter))
        self._hb_rng = random.Random()
        self._hb_gap = self._jittered_gap()
        self.send_timeout = send_timeout
        self.last_seen = time.monotonic()
        self.said_goodbye = False
        self._frames = _FrameBuffer()
        self._outbox: "queue.Queue[Optional[bytes]]" = queue.Queue(
            maxsize=max(1, outbox_size))
        self._send_failed: Optional[str] = None
        self._last_hb = 0.0
        self._closed = False
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self._sender = threading.Thread(
            target=self._drain_outbox, daemon=True,
            name=f"tcp-chan-sender-{sock.fileno()}")
        self._sender.start()

    # -- write side ---------------------------------------------------------
    def _drain_outbox(self) -> None:
        while True:
            payload = self._outbox.get()
            if payload is None:
                return
            try:
                self.sock.sendall(_FRAME.pack(len(payload)) + payload)
            except OSError as e:
                self._send_failed = f"send failed: {e!r}"
                return

    def send(self, msg: tuple) -> None:
        if self._closed or self._send_failed:
            raise ChannelClosed(self._send_failed or "channel closed")
        payload = pickle.dumps(msg, protocol=5)
        try:
            self._outbox.put(payload, timeout=self.send_timeout)
        except queue.Full:
            self._send_failed = (
                f"backpressure: peer did not drain {self._outbox.maxsize} "
                f"queued messages within {self.send_timeout}s")
            raise ChannelClosed(self._send_failed) from None

    def send_many(self, msgs: List[tuple]) -> None:
        """One frame for a burst of messages: a single pickle + a single
        outbox slot, amortizing serialization and syscall cost under
        load (order preserved; the peer unwraps)."""
        wrapped = wrap_batch(msgs)
        if wrapped is not None:
            self.send(wrapped)

    def _jittered_gap(self) -> float:
        return self.heartbeat_interval * \
            (1.0 - self.heartbeat_jitter * self._hb_rng.random())

    def maybe_heartbeat(self) -> None:
        now = time.monotonic()
        if now - self._last_hb < self._hb_gap:
            return
        self._last_hb = now
        self._hb_gap = self._jittered_gap()
        try:
            self.send(("hb",))
        except ChannelClosed:
            pass                     # dead() / next send reports it

    # -- read side ----------------------------------------------------------
    def selectable(self):
        return self.sock

    def recv_available(self) -> List[tuple]:
        try:
            data = self.sock.recv(1 << 20)
        except (BlockingIOError, InterruptedError):
            return []
        except OSError as e:
            raise ChannelClosed(f"recv failed: {e!r}") from e
        if not data:
            raise ChannelClosed("peer closed connection")
        self.last_seen = time.monotonic()
        msgs = _flatten_batches(self._frames.feed(data))
        if any(m and m[0] == "bye" for m in msgs):
            self.said_goodbye = True
        return msgs

    def dead(self) -> Optional[str]:
        if self._send_failed:
            return self._send_failed
        if self.said_goodbye:
            return None              # clean exit is not a crash
        silent = time.monotonic() - self.last_seen
        if silent > self.heartbeat_timeout:
            return (f"no heartbeat for {silent:.1f}s "
                    f"(timeout {self.heartbeat_timeout}s)")
        return None

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._outbox.put_nowait(None)
        except queue.Full:
            # make room for the shutdown sentinel (sends are refused now
            # that _closed is set), else the sender thread leaks blocked
            # in get() after the queue drains
            try:
                self._outbox.get_nowait()
            except queue.Empty:
                pass
            try:
                self._outbox.put_nowait(None)
            except queue.Full:
                pass
        # flush: queued messages (a final stop/die) should reach the wire
        # before the socket drops; a wedged peer bounds the wait
        self._sender.join(timeout=2.0)
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass


class WorkerTcpEndpoint:
    """Worker-side face of the TCP channel: blocking framed recv/send plus
    a background heartbeat thread and a driver-silence watchdog (a worker
    whose driver host vanished must not hang forever on a half-open
    socket — it exits, exactly as a pipe worker does on EOF).

    When the driver advertises a resumable run (:meth:`configure_rejoin`),
    a dead socket is no longer fatal: every send/recv failure funnels into
    :meth:`_try_rejoin`, which re-dials the driver address with a
    ``rejoin`` hello for up to ``window`` seconds, ships the worker's
    object-store inventory as the first frame on the fresh socket, and
    resumes.  Publishes queued during the outage simply block inside
    ``send`` until re-adoption — the worker keeps computing and buffers.
    Only after the window expires does the endpoint raise
    :class:`ChannelClosed` and let the worker die like an orphan.
    """

    supports_rejoin = True

    def __init__(self, sock: socket.socket, *,
                 heartbeat_interval: float = 2.0,
                 heartbeat_timeout: float = 30.0,
                 heartbeat_jitter: float = 0.25) -> None:
        self.sock = sock
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        self.heartbeat_jitter = max(0.0, min(0.9, heartbeat_jitter))
        self.last_seen = time.monotonic()
        self._send_lock = threading.Lock()
        self._stop = threading.Event()
        self._rejoin: Optional[dict] = None
        self._reconn_lock = threading.Lock()
        self._gen = 0                   # bumped on every successful rejoin
        self.rejoined = 0
        self.inventory_fn = None        # set by worker_main once the store
        #                                 exists: () -> [(tid, nbytes), ...]
        try:
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError:
            pass
        threading.Thread(target=self._heartbeat_loop, daemon=True,
                         name="worker-tcp-heartbeat").start()

    def configure_rejoin(self, *, address: str, token: Optional[str],
                         run_id: str, wid: int,
                         window: float = 60.0) -> None:
        """Arm driver-outage survival: on socket death, re-dial ``address``
        with a ``rejoin`` hello for this ``run_id``/``wid`` for up to
        ``window`` seconds before giving up."""
        self._rejoin = {"address": address, "token": token,
                        "run_id": run_id, "wid": wid, "window": window}

    def _heartbeat_loop(self) -> None:
        # random initial phase + per-beat jitter: N workers started by one
        # launcher would otherwise beat in lockstep and hit the driver as
        # one synchronized burst every interval.  Jitter only shortens the
        # gap (interval*(1-j)..interval), so timeout margins are unchanged.
        rng = random.Random()
        if self.heartbeat_jitter > 0 \
                and self._stop.wait(rng.random() * self.heartbeat_interval):
            return
        while not self._stop.wait(
                self.heartbeat_interval *
                (1.0 - self.heartbeat_jitter * rng.random())):
            try:
                self.send(("hb",))
            except ChannelClosed:
                return
            if time.monotonic() - self.last_seen > self.heartbeat_timeout:
                if self._rejoin is not None:
                    # Half-open socket during a resumable run: poke the
                    # blocked reader by closing the socket — its recv
                    # fails into _try_rejoin.  Must NOT exit: the rejoin
                    # window, not the heartbeat timeout, decides death,
                    # else a worker is counted dead once by the timeout
                    # and again at resume reconciliation.
                    try:
                        self.sock.close()
                    except OSError:
                        pass
                    self.last_seen = time.monotonic()
                    continue
                # driver silent past the deadline: orphaned worker.  Hard
                # exit mirrors the pipe worker's EOF death (daemonized
                # children of a dead driver must not linger).
                os._exit(1)

    def _try_rejoin(self, gen: int) -> bool:
        """Re-dial the driver after a socket failure observed at ``gen``.
        Returns True when a usable socket is in place (possibly installed
        by a racing thread), False when rejoin is off or the window
        expired."""
        rj = self._rejoin
        if rj is None or self._stop.is_set():
            return False
        with self._reconn_lock:
            if self._gen != gen:        # another thread already rejoined
                return True
            deadline = time.monotonic() + rj["window"]
            while not self._stop.is_set():
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                try:
                    sock, wid, _cfg, _blob = _dial_and_welcome(
                        rj["address"], token=rj["token"], has_graph=True,
                        timeout=min(5.0, max(0.5, left)),
                        retry_interval=0.2,
                        extra={"rejoin": rj["run_id"], "wid": rj["wid"]})
                except ChannelClosed:
                    time.sleep(0.25)
                    continue
                inv = list(self.inventory_fn()) if self.inventory_fn else []
                try:
                    sock.setsockopt(socket.IPPROTO_TCP,
                                    socket.TCP_NODELAY, 1)
                    # inventory is the FIRST frame on the new socket —
                    # written before the socket becomes visible to other
                    # sender threads, so the driver can reconcile before
                    # any buffered publish arrives
                    _send_frame(sock, pickle.dumps(
                        ("inv", rj["wid"], inv), protocol=5))
                except OSError:
                    try:
                        sock.close()
                    except OSError:
                        pass
                    time.sleep(0.25)
                    continue
                old, self.sock = self.sock, sock
                self._gen += 1
                self.last_seen = time.monotonic()
                self.rejoined += 1
                try:
                    old.close()
                except OSError:
                    pass
                return True
            return False

    def recv(self) -> tuple:
        while True:
            gen = self._gen
            try:
                msg = _recv_frame(self.sock)
            except (OSError, pickle.UnpicklingError, EOFError) as e:
                if self._try_rejoin(gen):
                    continue
                raise ChannelClosed(f"driver gone: {e!r}") from e
            self.last_seen = time.monotonic()
            return msg

    def send(self, msg: tuple) -> None:
        payload = pickle.dumps(msg, protocol=5)
        while True:
            gen = self._gen
            try:
                _send_frame(self.sock, payload, self._send_lock)
                return
            except OSError as e:
                if self._try_rejoin(gen):
                    continue
                raise ChannelClosed(f"driver gone: {e!r}") from e

    def close(self) -> None:
        self._rejoin = None             # a closing worker never re-dials
        self._stop.set()
        try:
            self.sock.close()
        except OSError:
            pass


# ----------------------------------------------------------------- listener
class TcpListener:
    """Driver-side accept loop for dialing workers.

    Binds ``host:port`` (port 0 = ephemeral; the resolved address is
    :attr:`address`), accepts connections on a background thread, performs
    the **server half of the handshake** — read the worker's ``hello``
    frame, check magic/version/token — and parks the authenticated
    ``(socket, hello)`` pair for the executor to adopt via
    :meth:`get_worker` (initial pool barrier) or :meth:`poll_worker`
    (mid-run elastic joins: any `repro-worker` that dials a live run is a
    join).  Rejected dials get a ``("reject", reason)`` frame and are
    closed; they never reach the executor.
    """

    def __init__(self, address: str = "127.0.0.1:0",
                 token: Optional[str] = None,
                 handshake_timeout: float = 10.0) -> None:
        host, _, port = address.rpartition(":")
        if not host:
            host, port = address or "127.0.0.1", "0"
        self.token = token
        self.handshake_timeout = handshake_timeout
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, int(port)))
        self._sock.listen(64)
        self.address = "%s:%d" % self._sock.getsockname()[:2]
        self._pending: "queue.Queue[Tuple[socket.socket, dict]]" = \
            queue.Queue()
        self._closed = False
        threading.Thread(target=self._accept_loop, daemon=True,
                         name=f"tcp-listener-{self.address}").start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._sock.accept()
            except OSError:
                return
            threading.Thread(target=self._handshake, args=(sock,),
                             daemon=True).start()

    def _handshake(self, sock: socket.socket) -> None:
        try:
            sock.settimeout(self.handshake_timeout)
            # SECURITY: the hello is the ONLY frame read from an
            # unauthenticated peer, and it is JSON — pickle.loads on
            # pre-auth bytes would hand arbitrary code execution to
            # anyone who can reach the port, making the token check
            # decorative.  Pickled frames start after the token passes.
            import json
            try:
                info = json.loads(
                    _recv_raw_frame(sock, max_len=1 << 16).decode("utf-8"))
            except (ValueError, UnicodeDecodeError) as e:
                raise ChannelClosed(f"malformed hello: {e!r}") from e
            if not isinstance(info, dict):
                raise ChannelClosed("malformed hello")
            if info.get("magic") != PROTOCOL_MAGIC:
                raise ChannelClosed("bad magic")
            if info.get("version") != PROTOCOL_VERSION:
                raise ChannelClosed(
                    f"protocol version {info.get('version')} != "
                    f"{PROTOCOL_VERSION}")
            if self.token is not None:
                # constant-time comparison, matching the peer data plane's
                # capability check (serde.PeerServer): a plain `!=` leaks
                # the shared token byte-by-byte through response timing
                import hmac
                tok = info.get("token")
                if not (isinstance(tok, str) and hmac.compare_digest(
                        tok.encode("utf-8"), self.token.encode("utf-8"))):
                    raise ChannelClosed("bad token")
            try:
                info["peer_ip"] = sock.getpeername()[0]
            except OSError:
                info["peer_ip"] = "127.0.0.1"
            sock.settimeout(None)
        except (ChannelClosed, OSError, pickle.UnpicklingError,
                EOFError) as e:
            try:
                _send_frame(sock, pickle.dumps(("reject", repr(e)),
                                               protocol=5))
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
            return
        self._pending.put((sock, info))

    def get_worker(self, timeout: float) -> Tuple[socket.socket, dict]:
        """Block until a handshaken worker connection is available."""
        try:
            return self._pending.get(timeout=timeout)
        except queue.Empty:
            raise TimeoutError(
                f"no worker dialed {self.address} within {timeout}s "
                "(start workers with: python -m repro.launch.remote "
                f"--connect {self.address})") from None

    def poll_worker(self) -> Optional[Tuple[socket.socket, dict]]:
        """Non-blocking variant for mid-run elastic joins."""
        try:
            return self._pending.get_nowait()
        except queue.Empty:
            return None

    def fileno(self) -> int:
        """The listening socket's fd — fork-started workers close this
        inherited copy so a dead driver's port frees for a resumed one."""
        return self._sock.fileno()

    def close(self) -> None:
        self._closed = True
        # shutdown-before-close: the accept thread is blocked in accept(2),
        # and on Linux close() alone does NOT wake it — the in-flight
        # syscall keeps the kernel socket (and the PORT) alive until some
        # stray dial lands.  A driver restarted on the same address would
        # race that zombie LISTEN and lose with EADDRINUSE.
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass


# --------------------------------------------------------------- worker dial
def _dial_and_welcome(address: str, *, token: Optional[str],
                      has_graph: bool, timeout: float,
                      retry_interval: float,
                      extra: Optional[dict] = None,
                      ) -> Tuple[socket.socket, int, dict, Optional[bytes]]:
    """Connect + hello + welcome, returning the raw authenticated socket.
    Shared between the first dial (:func:`dial_driver`) and the rejoin
    path (:meth:`WorkerTcpEndpoint._try_rejoin`), which differ only in
    the ``extra`` hello fields."""
    host, _, port = address.rpartition(":")
    if not host:
        raise ValueError(f"worker address must be host:port, got {address!r}")
    deadline = time.monotonic() + timeout
    last_err: Optional[BaseException] = None
    sock: Optional[socket.socket] = None
    while sock is None:
        try:
            sock = socket.create_connection((host, int(port)),
                                            timeout=timeout)
        except OSError as e:
            last_err = e
            if time.monotonic() >= deadline:
                raise ChannelClosed(
                    f"could not reach driver at {address}: {e!r}") from e
            time.sleep(retry_interval)
    import json
    hello = {"magic": PROTOCOL_MAGIC,
             "version": PROTOCOL_VERSION,
             "token": token,
             "host": host_id(),
             "pid": os.getpid(),
             "has_graph": has_graph}
    hello.update(extra or {})
    try:
        sock.settimeout(timeout)
        # hello is JSON (see TcpListener._handshake: the driver must not
        # unpickle pre-auth bytes); everything after it is pickled frames
        _send_frame(sock, json.dumps(hello).encode("utf-8"))
        reply = _recv_frame(sock)
    except (OSError, pickle.UnpicklingError, EOFError) as e:
        try:
            sock.close()
        except OSError:
            pass
        raise ChannelClosed(
            f"handshake with {address} failed: {e!r}") from (last_err or e)
    if reply and reply[0] == "reject":
        sock.close()
        raise DialRejected(f"driver rejected worker: {reply[1]}")
    if not (reply and reply[0] == "welcome" and len(reply) == 4):
        sock.close()
        raise ChannelClosed(f"unexpected handshake reply {reply!r}")
    _, wid, config, graph_blob = reply
    sock.settimeout(None)
    return sock, wid, config, graph_blob


def dial_driver(address: str, *, token: Optional[str] = None,
                has_graph: bool = False, timeout: float = 30.0,
                retry_interval: float = 0.2,
                heartbeat_interval: float = 2.0,
                heartbeat_timeout: float = 30.0,
                retry=None,
                ) -> Tuple[WorkerTcpEndpoint, int, dict, Optional[bytes]]:
    """Worker half of the handshake: connect to ``address``, send hello,
    await the driver's welcome.

    Retries the connect until ``timeout`` (workers routinely start before
    the driver binds), and retries *handshake* failures — a dial the
    driver accepted but whose welcome died mid-flight (restarting driver,
    flaky link, injected accept fault) — under ``retry``
    (a :class:`repro.faults.RetryPolicy`; default: 4 attempts with
    exponential backoff inside ``timeout``).  A :class:`DialRejected`
    (bad token, version skew) is definitive and never retried.  Returns
    ``(endpoint, wid, config, graph_blob)`` — ``graph_blob`` is the
    pickled ``(graph, inputs)`` pair for workers that did not inherit the
    graph (``has_graph=False``), else ``None``.

    When the welcome config names a resumable run (``run_id``), the
    endpoint is armed to survive a driver outage: it re-dials ``address``
    with a ``rejoin`` hello instead of dying with the socket.
    """
    if retry is None:
        from repro.faults.retry import RetryPolicy
        retry = RetryPolicy(attempts=4, base_delay=0.2, factor=2.0,
                            max_delay=2.0, deadline=timeout)

    def attempt(_i: int):
        return _dial_and_welcome(
            address, token=token, has_graph=has_graph, timeout=timeout,
            retry_interval=retry_interval)

    sock, wid, config, graph_blob = retry.run(
        attempt,
        retryable=lambda e: isinstance(e, ChannelClosed)
        and not isinstance(e, DialRejected))
    endpoint = WorkerTcpEndpoint(
        sock,
        heartbeat_interval=config.get("heartbeat_interval",
                                      heartbeat_interval),
        heartbeat_timeout=config.get("worker_heartbeat_timeout",
                                     heartbeat_timeout),
        heartbeat_jitter=config.get("heartbeat_jitter", 0.25))
    if config.get("run_id"):
        endpoint.configure_rejoin(
            address=address, token=token, run_id=config["run_id"], wid=wid,
            window=config.get("rejoin_window", 60.0))
    return endpoint, wid, config, graph_blob
