"""ClusterExecutor — the multi-process distributed runtime.

This is the paper's driver/worker architecture made real on one host:
OS-process workers (the stand-in for cluster nodes — same protocol, a
socket transport is a drop-in follow-up), a driver that schedules ready
tasks onto them, a driver-side :class:`DriverObjectStore` tracking where
every result lives, and lineage-based recovery when a worker dies.

Design points (mirroring the Haskell#/Cloud-Haskell driver designs and the
mapping-decision framing of Mapple):

* **Static plan, dynamic execution.**  ``scheduler.list_schedule`` produces
  a placement hint (critical-path priority, earliest-finish-time worker);
  the driver follows it opportunistically and *steals* — dispatches a ready
  task to an idle worker that wasn't its planned home — whenever the plan
  goes stale, so heterogeneity or stragglers never serialize the run.
* **Pipelined dispatch.**  Up to ``pipeline_depth`` tasks are in a worker's
  pipe at once, so the driver overlaps dispatch/transfer with execution
  (the futures-style async core of ``submit``/``gather``).
* **Ownership, not broadcast.**  Results stay in the producing worker's
  local store; the driver pulls a value only when a consumer lands on a
  different worker (driver-mediated transfer, cached → durable) or at
  final collection.  Locality-aware dispatch makes most transfers no-ops.
* **Lineage fault tolerance.**  On worker death the lost set is exactly
  ``owned(worker) - driver_cache``; ``lineage.recovery_plan`` gives the
  minimal recompute set (walking past GC'd ancestors in ``outputs_only``
  runs), ``scheduler.replan`` re-places the remaining work on the
  survivors, and ``stats["recomputed"]`` counts exactly ``len(plan)``.
* **Elasticity.**  ``add_worker()`` forks a fresh worker mid-run and
  replans onto the grown pool.

Failure injection for tests/benchmarks: ``fail_worker=(wid, n)`` SIGKILLs
worker ``wid`` after it completes ``n`` tasks; ``join_after=(n, k)`` forks
``k`` extra workers once ``n`` tasks have completed cluster-wide.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import signal
import threading
import time
from dataclasses import dataclass, field
from multiprocessing.connection import wait as conn_wait
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.executor import MissingInput, TaskFailed
from repro.core.graph import TaskGraph
from repro.core.lineage import recovery_plan
from repro.core.scheduler import list_schedule, replan

from .futures import ClusterFuture
from .objectstore import DriverObjectStore
from .worker import worker_main

PENDING, READY, WAITING, INFLIGHT, DONE = range(5)


@dataclass
class _Worker:
    wid: int
    proc: Any
    conn: Any
    alive: bool = True
    inflight: Set[int] = field(default_factory=set)   # run sent, not done
    assigned: Set[int] = field(default_factory=set)   # waiting on transfers
    n_done: int = 0

    def load(self) -> int:
        return len(self.inflight) + len(self.assigned)


class ClusterExecutor:
    """Executes a :class:`TaskGraph` on ``n_workers`` forked processes.

    Satisfies the :class:`repro.core.executor.Executor` protocol — results
    are bit-identical to :func:`repro.core.executor.execute_sequential`
    because tasks are pure and the value tables are exact.

    ``outputs_only=True`` returns just ``{tid: value for tid in outputs}``
    and garbage-collects intermediates once their last consumer finishes —
    the memory-bounded production mode, and the mode where lineage recovery
    has to recompute *dropped* ancestors, not only directly lost values.
    """

    def __init__(
        self,
        n_workers: int = 2,
        *,
        policy: str = "critical_path",
        worker_speed: Optional[Sequence[float]] = None,
        pipeline_depth: int = 2,
        outputs_only: bool = False,
        fail_worker: Optional[Tuple[int, int]] = None,
        join_after: Optional[Tuple[int, int]] = None,
        progress_timeout: float = 60.0,
        start_method: str = "fork",
        seed: int = 0,
    ) -> None:
        if n_workers < 1:
            raise ValueError("n_workers >= 1")
        if start_method not in ("fork", "spawn", "forkserver"):
            raise ValueError(f"unknown start_method {start_method!r}")
        self.start_method = start_method
        self.n_workers = n_workers
        self.policy = policy
        self.worker_speed = list(worker_speed) if worker_speed else None
        self.pipeline_depth = max(1, pipeline_depth)
        self.outputs_only = outputs_only
        self.fail_worker = fail_worker
        self.join_after = join_after
        self.progress_timeout = progress_timeout
        self.seed = seed
        self.stats: Dict[str, int] = {}
        self.wall_time = 0.0
        self.recovery_events: List[Dict[str, Any]] = []
        self._commands: List[Tuple] = []
        self._cmd_lock = threading.Lock()
        # stats/recovery_events/wall_time are per-run instance attributes,
        # so one executor runs ONE graph at a time; concurrent submissions
        # queue on this lock (use separate executors for parallel jobs)
        self._run_lock = threading.Lock()
        self._active = False

    # ------------------------------------------------------------- frontend
    def run(self, graph: TaskGraph,
            inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
        return self._execute(graph, inputs)

    def submit(self, graph: TaskGraph,
               inputs: Optional[Dict[str, Any]] = None,
               label: str = "") -> ClusterFuture:
        """Async submission: returns immediately with a future; the run
        executes on a background driver thread with a fresh worker pool.
        Runs on the SAME executor serialize (stats are per-run) — use one
        executor per job for true inter-job concurrency."""
        fut = ClusterFuture(label)

        def drive() -> None:
            try:
                fut._set_result(self._execute(graph, inputs))
            except BaseException as e:   # noqa: BLE001 — carried by future
                fut._set_error(e)

        threading.Thread(target=drive, daemon=True,
                         name=f"cluster-driver-{label or id(fut)}").start()
        return fut

    def add_worker(self) -> None:
        """Elastic join: grow the pool (mid-run if a run is active)."""
        with self._cmd_lock:
            if self._active:
                self._commands.append(("join",))
            else:
                self.n_workers += 1

    def kill_worker(self, wid: int) -> None:
        """Chaos hook: SIGKILL worker ``wid`` of the active run."""
        with self._cmd_lock:
            self._commands.append(("kill", wid))

    # -------------------------------------------------------------- driver
    def _execute(self, graph: TaskGraph,
                 inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        graph.validate()
        with self._run_lock:
            return self._execute_locked(graph, inputs)

    def _execute_locked(self, graph: TaskGraph,
                        inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        ctx = mp.get_context(self.start_method)
        stats = self.stats = {
            "dispatched": 0, "steals": 0, "transfers": 0, "recomputed": 0,
            "failures": 0, "joins": 0, "dropped": 0,
        }
        self.recovery_events = []
        t0 = time.perf_counter()

        store = DriverObjectStore(graph)
        workers: Dict[int, _Worker] = {}
        next_wid = 0

        def spawn() -> _Worker:
            nonlocal next_wid
            wid = next_wid
            next_wid += 1
            parent, child = ctx.Pipe(duplex=True)
            proc = ctx.Process(target=worker_main,
                               args=(wid, child, graph, inputs),
                               daemon=True, name=f"cluster-worker-{wid}")
            proc.start()
            child.close()
            w = _Worker(wid, proc, parent)
            workers[wid] = w
            store.add_worker(wid)
            return w

        for _ in range(self.n_workers):
            spawn()

        rank = graph.critical_path_rank()
        succ = store.successors
        n_total = len(graph.nodes)
        required = (set(graph.outputs) if self.outputs_only
                    else set(graph.nodes))

        state: Dict[int, int] = {}
        for tid, node in graph.nodes.items():
            state[tid] = READY if not node.all_deps else PENDING
        done: Set[int] = set()
        finish_times: Dict[int, float] = {}
        # tid -> (wid, still-missing dep tids) for transfer-blocked dispatches
        waiting: Dict[int, Tuple[int, Set[int]]] = {}
        fetching: Set[int] = set()          # dep tids with a fetch in flight
        error: List[BaseException] = []
        join_after = self.join_after     # consumed per run, not per executor
        last_progress = time.perf_counter()

        def alive_ids() -> List[int]:
            return [w.wid for w in workers.values() if w.alive]

        def speeds_for(wids: List[int]) -> Optional[List[float]]:
            if self.worker_speed is None:
                return None
            return [self.worker_speed[w % len(self.worker_speed)]
                    for w in wids]

        # planned placement: schedule slot i -> i-th alive worker id
        plan_worker: Dict[int, int] = {}

        def make_plan(initial: bool) -> None:
            wids = alive_ids()
            if not wids:
                return
            try:
                if initial:
                    sched = list_schedule(
                        graph, len(wids), policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed)
                else:
                    sched = replan(
                        graph, dict(finish_times), len(wids),
                        now=time.perf_counter() - t0, policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed)
            except Exception:            # plan is advisory; never fatal
                plan_worker.clear()
                return
            plan_worker.clear()
            for tid, p in sched.placements.items():
                plan_worker[tid] = wids[p.worker]

        make_plan(initial=True)

        # ---------------------------------------------------------- helpers
        def safe_send(w: _Worker, msg: tuple) -> bool:
            """Send to a worker; an already-dead peer (organic SIGKILL, OOM,
            segfault) becomes a failure-handled event, never an exception
            out of the driver loop."""
            try:
                w.conn.send(msg)
                return True
            except (BrokenPipeError, OSError):
                on_worker_death(w)
                return False

        def try_dispatch(tid: int, w: _Worker) -> bool:
            """Assign READY task ``tid`` to worker ``w``; ship or fetch
            whatever remote inputs it needs.  Returns False when a recovery
            ran underneath (caller must re-snapshot the ready set)."""
            node = graph.nodes[tid]
            extra: Dict[int, Any] = {}
            missing: Set[int] = set()
            for d in node.all_deps:
                if store.location(d) == w.wid:
                    continue                       # already local
                if d in store.cache:
                    extra[d] = store.cache[d]      # ship with the dispatch
                else:
                    missing.add(d)
            if missing:
                # a "done" dep with no live owner and no cached copy is a
                # lost value the death handler didn't see (e.g. GC raced a
                # transfer): recover it through lineage like any other loss
                unreachable = {
                    d for d in missing if d not in fetching
                    and (store.location(d) is None
                         or not workers[store.location(d)].alive)}
                if unreachable:
                    state[tid] = READY
                    recompute_lost(unreachable, unreachable, None)
                    return False
                state[tid] = WAITING
                waiting[tid] = (w.wid, missing)
                w.assigned.add(tid)
                for d in missing:
                    if d not in fetching:
                        if not safe_send(workers[store.location(d)],
                                         ("fetch", d)):
                            return False    # owner died; recovery ran
                        fetching.add(d)
                        stats["transfers"] += 1
                return True
            stats["transfers"] += len(extra)
            state[tid] = INFLIGHT
            w.inflight.add(tid)
            if not safe_send(w, ("run", tid, extra)):
                return False        # death handler reset tid to READY
            stats["dispatched"] += 1
            return True

        def finish_waiting(tid: int) -> None:
            """All transfers for a WAITING task arrived — launch it."""
            wid, _ = waiting.pop(tid)
            w = workers[wid]
            w.assigned.discard(tid)
            if not w.alive:
                state[tid] = READY
                return
            node = graph.nodes[tid]
            extra = {d: store.cache[d] for d in node.all_deps
                     if store.location(d) != wid and d in store.cache}
            state[tid] = INFLIGHT
            w.inflight.add(tid)
            if not safe_send(w, ("run", tid, extra)):
                return              # death handler reset tid to READY
            stats["dispatched"] += 1
            stats["transfers"] += len(extra)

        def dispatch() -> None:
            ready = [t for t, s in state.items() if s == READY]
            if not ready:
                return
            ready.sort(key=lambda t: (-rank[t], t))
            for w in list(workers.values()):
                if not w.alive:
                    continue
                while w.load() < self.pipeline_depth and ready:
                    mine = next((t for t in ready
                                 if plan_worker.get(t, w.wid) == w.wid), None)
                    if mine is None:
                        mine = ready[0]            # steal off-plan work
                        stats["steals"] += 1
                    ready.remove(mine)
                    if state.get(mine) != READY:
                        continue    # demoted since the snapshot
                    if not try_dispatch(mine, w):
                        return      # recovery invalidated the snapshot

        def maybe_gc(tid: int) -> None:
            if not self.outputs_only or not store.collectable(tid):
                return
            owner = store.location(tid)
            if owner is not None and workers[owner].alive:
                safe_send(workers[owner], ("drop", [tid]))
            store.invalidate({tid})
            stats["dropped"] += 1

        def on_done(w: _Worker, tid: int, wall: float) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(tid)
            if state.get(tid) == DONE:
                return                              # stale duplicate
            state[tid] = DONE
            done.add(tid)
            finish_times[tid] = time.perf_counter() - t0
            store.record(tid, w.wid)
            w.n_done += 1
            for d in graph.nodes[tid].all_deps:
                store.consumed(d)
                maybe_gc(d)
            for s in succ[tid]:
                if state[s] == PENDING and \
                        all(state[d] == DONE for d in graph.nodes[s].all_deps):
                    state[s] = READY
            if self.fail_worker and w.wid == self.fail_worker[0] \
                    and w.n_done >= self.fail_worker[1] and w.alive:
                kill(w)
            nonlocal join_after
            if join_after and len(done) >= join_after[0]:
                n_new, join_after = join_after[1], None
                for _ in range(n_new):
                    join_one()

        def kill(w: _Worker) -> None:
            """SIGKILL + immediate failure handling (used by injection and
            the kill_worker command; organic deaths arrive via the pipe)."""
            try:
                os.kill(w.proc.pid, signal.SIGKILL)
                w.proc.join(timeout=5.0)
            except (ProcessLookupError, OSError):
                pass
            on_worker_death(w)

        def join_one() -> None:
            w = spawn()
            stats["joins"] += 1
            make_plan(initial=False)
            return w

        def recompute_lost(needed: Set[int], lost: Set[int],
                           cause: Any) -> None:
            """Lineage recovery: schedule the minimal recompute set for
            ``needed`` lost values, then replan onto the live workers."""
            available = store.available(set(alive_ids()))
            plan = recovery_plan(graph, needed, available)
            stats["recomputed"] += len(plan)
            self.recovery_events.append({
                "worker": cause, "lost": set(lost), "needed": set(needed),
                "available": set(available), "plan": set(plan),
            })

            will_run = plan | {t for t, s in state.items() if s != DONE}
            store.invalidate(plan)
            store.reset_consumers(plan, will_run)
            for t in plan:                  # deps outside the plan get re-read
                for d in graph.nodes[t].all_deps:
                    if d not in plan:
                        store.consumers_left[d] = \
                            store.consumers_left.get(d, 0) + 1
            for t in plan:
                done.discard(t)
                finish_times.pop(t, None)
            # WAITING tasks elsewhere may block on a lost value: reset them
            for tid in list(waiting):
                wid, need = waiting[tid]
                if need & plan:
                    waiting.pop(tid)
                    workers[wid].assigned.discard(tid)
                    state[tid] = READY
            for t in plan:
                state[t] = (READY if all(state[d] == DONE
                                         for d in graph.nodes[t].all_deps)
                            else PENDING)
            # demote READY tasks whose deps just un-completed
            for tid, s in list(state.items()):
                if s == READY and any(state[d] != DONE
                                      for d in graph.nodes[tid].all_deps):
                    state[tid] = PENDING

            if not alive_ids():
                error.append(RuntimeError(
                    "cluster lost every worker; cannot recover"))
                return
            make_plan(initial=False)       # replan onto the survivors

        def on_worker_death(w: _Worker) -> None:
            nonlocal last_progress
            if not w.alive:
                return
            last_progress = time.perf_counter()
            w.alive = False
            try:
                w.conn.close()
            except OSError:
                pass
            stats["failures"] += 1

            # tasks that never completed there simply go back in the pool
            for tid in list(w.inflight):
                state[tid] = READY
            w.inflight.clear()
            for tid in list(w.assigned):
                waiting.pop(tid, None)
                state[tid] = READY
            w.assigned.clear()

            # results that lived only in its store are lost -> lineage
            lost = store.drop_worker(w.wid)
            fetching.difference_update(lost)       # those replies never come
            if self.outputs_only:
                needed = {t for t in lost
                          if t in graph.outputs
                          or store.consumers_left.get(t, 0) > 0}
            else:
                needed = set(lost)
            recompute_lost(needed, lost, w.wid)

        def on_value(w: _Worker, tid: int, found: bool, value: Any) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            fetching.discard(tid)
            if not found:
                # owner dropped/lost it between request and reply; treat the
                # value as lost and recover exactly like a partial failure
                if state.get(tid) == DONE and tid not in store.cache:
                    store.invalidate({tid})
                    recompute_lost({tid}, {tid}, None)
                return
            store.cache_value(tid, value)
            for t in list(waiting):
                entry = waiting.get(t)
                if entry is None:     # popped by a recovery mid-loop
                    continue
                _, need = entry
                need.discard(tid)
                if not need:
                    finish_waiting(t)

        def pump(timeout: float) -> None:
            nonlocal last_progress
            conns = {w.conn: w for w in workers.values() if w.alive}
            if not conns:
                return
            for conn in conn_wait(list(conns), timeout=timeout):
                w = conns[conn]
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    on_worker_death(w)
                    continue
                verb = msg[0]
                if verb == "done":
                    on_done(w, msg[2], msg[3])
                elif verb == "value":
                    on_value(w, msg[2], msg[3], msg[4])
                elif verb == "error":
                    if msg[3] == "MissingInput":
                        # caller-error contract: never wrapped in TaskFailed
                        error.append(MissingInput(msg[4]))
                    else:
                        error.append(TaskFailed(
                            msg[2], graph.nodes[msg[2]].name,
                            RuntimeError(f"{msg[3]}: {msg[4]}")))
                elif verb == "bye":
                    pass

        def check_commands() -> None:
            with self._cmd_lock:
                cmds, self._commands = self._commands, []
            for cmd in cmds:
                if cmd[0] == "join":
                    join_one()
                elif cmd[0] == "kill" and cmd[1] in workers \
                        and workers[cmd[1]].alive:
                    kill(workers[cmd[1]])

        def check_deaths() -> None:
            for w in list(workers.values()):
                if w.alive and not w.proc.is_alive():
                    on_worker_death(w)

        # ------------------------------------------------------- main loop
        self._active = True
        try:
            while not error:
                check_commands()
                if len(done) >= n_total:
                    missing = [t for t in required if t not in store.cache]
                    if not missing:
                        break
                    for t in missing:       # final collection
                        if t in fetching:
                            continue
                        owner = store.location(t)
                        if owner is not None and workers[owner].alive:
                            if not safe_send(workers[owner], ("fetch", t)):
                                break       # recovery ran; resume main loop
                            fetching.add(t)
                else:
                    dispatch()
                pump(timeout=0.02)
                check_deaths()
                if time.perf_counter() - last_progress > self.progress_timeout:
                    by_state: Dict[int, List[int]] = {}
                    for t, s in state.items():
                        by_state.setdefault(s, []).append(t)
                    error.append(RuntimeError(
                        f"cluster made no progress for "
                        f"{self.progress_timeout}s "
                        f"(done {len(done)}/{n_total}, states "
                        f"{ {s: sorted(ts)[:8] for s, ts in by_state.items() if s != DONE} }, "
                        f"waiting {dict(list(waiting.items())[:4])}, "
                        f"fetching {sorted(fetching)[:8]}, "
                        f"inflight {[sorted(w.inflight) for w in workers.values()]})"))
        finally:
            self._active = False
            for w in workers.values():
                if w.alive:
                    try:
                        w.conn.send(("stop",))
                    except (BrokenPipeError, OSError):
                        pass
            for w in workers.values():
                w.proc.join(timeout=5.0)
                if w.proc.is_alive():
                    w.proc.terminate()
            self.wall_time = time.perf_counter() - t0

        if error:
            raise error[0]
        return {t: store.cache[t] for t in required}
