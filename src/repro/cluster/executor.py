"""ClusterExecutor — the multi-process / multi-host distributed runtime.

This is the paper's driver/worker architecture made real: workers are OS
processes on this host (forked or spawned, wired by duplex pipes) or on
*any* host (dialed in over TCP), a driver that schedules ready tasks onto
them, a driver-side :class:`DriverObjectStore` tracking where every result
lives, and lineage-based recovery when a worker dies.  The driver speaks
to every worker through the :class:`~repro.cluster.channel.Channel`
abstraction, so none of the scheduling/recovery logic below knows (or
cares) what wire its messages ride.

Design points (mirroring the Haskell#/Cloud-Haskell driver designs and the
mapping-decision framing of Mapple):

* **Static plan, dynamic execution.**  ``scheduler.list_schedule`` produces
  a placement hint (critical-path priority, earliest-finish-time worker);
  the driver follows it opportunistically and *steals* — dispatches a ready
  task to an idle worker that wasn't its planned home — whenever the plan
  goes stale.  Both the plan (via ``data_sizes``/``placed``/``worker_host``
  comm costs in the scheduler) and the stealing choice (via a transfer-cost
  score over per-value sizes recorded at completion) are **locality-aware**
  at two radii: same-worker beats same-host beats cross-host, so a
  consumer lands next to its bytes and cross-host TCP pulls are a last
  resort.
* **Zero-copy data plane.**  Cross-worker values move as *handles*
  (:mod:`repro.cluster.serde`): the owner publishes the payload once into
  a ``multiprocessing.shared_memory`` segment (or serves it over its
  unix/TCP socket server), and the consumer maps/pulls it directly.  The
  control channel carries only messages and handles —
  ``stats["bytes_driver"]`` vs ``stats["bytes_direct"]`` make the split
  observable; ``transport="driver"`` restores the PR-1 relay for A/B runs.
* **Channel-based liveness.**  A forked worker's death is OS truth
  (``proc.is_alive``); a TCP worker's death is **missed heartbeats** or a
  socket EOF — and a clean shutdown says an explicit goodbye so it is
  never misread as a crash.  The driver asks each channel, not the
  process table, so SIGKILL on another machine and SIGKILL on this one
  take the same recovery path.
* **Pipelined dispatch.**  Up to ``pipeline_depth`` tasks are in a worker's
  channel at once, so the driver overlaps dispatch/transfer with execution
  (the futures-style async core of ``submit``/``gather``).
* **Replicas, not broadcast.**  Results stay in the producing worker's
  local store; a transfer leaves the consumer holding a replica (tracked
  per-value as a *set* of holders, each tagged with its host), so later
  consumers read locally and a value is only lost when its last holder
  dies without a durable handle.
* **Lineage fault tolerance.**  On worker death the lost set is exactly
  the values with no surviving replica, no shm-published handle, and no
  driver-cached copy; ``lineage.recovery_plan`` gives the minimal
  recompute set (walking past GC'd ancestors in ``outputs_only`` runs),
  ``scheduler.replan`` re-places the remaining work on the survivors, and
  ``stats["recomputed"]`` counts exactly ``len(plan)``.  A SIGKILL
  mid-transfer degrades the same way: consumers that already hold a stale
  handle report ``deplost`` and the task re-queues behind the recovery.
* **Speculative re-execution of stragglers.**  Purity makes duplication
  free, so with ``speculate_after=x`` an *idle* worker (no ready work
  anywhere) duplicates the most-overdue running task — one running more
  than ``x×`` its expected duration, where *expected* is the static
  ``list_schedule`` cost-model hint calibrated into seconds by a runtime
  EWMA of actual-vs-planned durations.  The first completion wins; losers
  get an idempotent ``cancel`` (honored between tasks — a loser already
  executing finishes and its late ``done`` is reconciled: recorded as a
  legitimate extra replica, or swept when the GC already dropped the
  value).  The *pick* is :func:`repro.core.simulator.pick_speculation`,
  shared with the simulator so policy and model provably agree.
  ``stats`` reports ``n_speculative`` / ``speculative_wins`` /
  ``speculative_wasted_s``; see ``docs/speculation.md``.
* **Elasticity.**  ``add_worker()`` forks a fresh worker mid-run and
  replans onto the grown pool; on a TCP control plane, any
  ``repro-worker`` that dials the driver's address mid-run joins the same
  way.
* **Segment hygiene.**  The driver is the single unlink authority:
  handles are released when the ``consumers_left`` GC drains a value
  (``outputs_only`` runs unlink eagerly), and a run-scoped shutdown sweep
  catches ``/dev/shm`` orphans *and* stale peer-socket files from workers
  killed mid-publish.  No segment or socket file survives executor
  shutdown.

Failure injection for tests/benchmarks: ``fail_worker=(wid, n)`` SIGKILLs
worker ``wid`` after it completes ``n`` tasks (a remote worker is sent a
``die`` message instead — the driver cannot signal a remote pid);
``join_after=(n, k)`` starts ``k`` extra workers once ``n`` tasks have
completed cluster-wide.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import pickle
import signal
import tempfile
import threading
import time
import uuid
from dataclasses import dataclass, field
from multiprocessing.connection import wait as conn_wait
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.executor import MissingInput, TaskFailed
from repro.core.graph import TaskGraph
from repro.core.lineage import recovery_plan
from repro.core.scheduler import list_schedule, replan
from repro.core.simulator import pick_speculation

from . import serde
from .channel import (CHANNELS, ChannelClosed, PipeChannel, SpawnChannel,
                      TcpChannel, TcpListener, host_id, routable_ip)
from .futures import ClusterFuture
from .objectstore import DriverObjectStore
from .worker import pipe_worker_main, tcp_worker_main

PENDING, READY, WAITING, INFLIGHT, DONE = range(5)

WORKER_SPECS = ("local", "remote")


@dataclass
class _Worker:
    wid: int
    chan: Any                       # driver-side Channel
    host: str                       # machine identity (locality grouping)
    proc: Any = None                # local process handle; None for remote
    alive: bool = True
    inflight: Set[int] = field(default_factory=set)   # run sent, not done
    assigned: Set[int] = field(default_factory=set)   # waiting on transfers
    n_done: int = 0

    def load(self) -> int:
        return len(self.inflight) + len(self.assigned)


class ClusterExecutor:
    """Executes a :class:`TaskGraph` on a pool of worker processes.

    Satisfies the :class:`repro.core.executor.Executor` protocol — results
    are bit-identical to :func:`repro.core.executor.execute_sequential`
    because tasks are pure and the value tables are exact.

    **Control plane** (``channel``): ``"pipe"`` (forked in-host workers,
    the default), ``"spawn"`` (fresh-interpreter in-host workers; implied
    by ``start_method="spawn"``), or ``"tcp"`` (workers dial the driver's
    listening address — the multi-host channel, with heartbeat liveness).
    With ``channel="tcp"`` the driver binds ``connect`` (default
    ``127.0.0.1:0``; the resolved address is :attr:`address`) and
    ``workers`` describes the pool: ``"local"`` entries are forked dialers
    started by the driver, ``"remote"`` entries are slots filled by
    external ``repro-worker`` processes (``python -m repro.launch.remote
    --connect <address>``) within ``accept_timeout``.  Extra dials during
    a run join elastically.

    **Data plane** (``transport``): ``"shm"`` (zero-copy shared memory),
    ``"sock"`` (direct unix-socket pulls), ``"tcp"`` (direct TCP pulls —
    the only bulk channel that crosses hosts), ``"driver"`` (relay through
    the control channel), or ``"auto"`` (best available; ``tcp`` when the
    pool spans hosts).  ``shm_threshold`` is the payload size at which
    values leave the control channel.  The resolved choice of an ``auto``
    run is exposed as ``transport_used`` after ``run``.

    ``outputs_only=True`` returns just ``{tid: value for tid in outputs}``
    and garbage-collects intermediates once their last consumer finishes —
    the memory-bounded production mode, where shm segments are unlinked
    eagerly and lineage recovery recomputes *dropped* ancestors too.

    ``speculate_after=x`` enables speculative re-execution of stragglers:
    an idle worker duplicates a task running longer than ``x×`` its
    expected duration, first completion wins, the loser is cancelled
    between tasks.  Off (``None``) by default — duplication costs work, so
    it is opt-in for tail-latency-sensitive runs (``docs/speculation.md``).
    """

    def __init__(
        self,
        n_workers: int = 2,
        *,
        policy: str = "critical_path",
        worker_speed: Optional[Sequence[float]] = None,
        pipeline_depth: int = 2,
        outputs_only: bool = False,
        fail_worker: Optional[Tuple[int, int]] = None,
        join_after: Optional[Tuple[int, int]] = None,
        progress_timeout: float = 60.0,
        start_method: str = "fork",
        seed: int = 0,
        transport: str = "auto",
        shm_threshold: int = serde.SHM_THRESHOLD,
        bandwidth: float = float(256 << 20),
        channel: Optional[str] = None,
        connect: Optional[str] = None,
        workers: Optional[Sequence[str]] = None,
        token: Optional[str] = None,
        accept_timeout: float = 60.0,
        heartbeat_interval: float = 1.0,
        heartbeat_timeout: float = 15.0,
        speculate_after: Optional[float] = None,
    ) -> None:
        if start_method not in ("fork", "spawn", "forkserver"):
            raise ValueError(f"unknown start_method {start_method!r}")
        if workers is not None:
            workers = list(workers)
            bad = [w for w in workers if w not in WORKER_SPECS]
            if bad:
                raise ValueError(f"unknown worker spec(s) {bad!r} "
                                 f"(expected one of {WORKER_SPECS})")
            n_workers = len(workers)
        if n_workers < 1:
            raise ValueError("n_workers >= 1")
        self.worker_specs = workers or ["local"] * n_workers
        self.multihost = "remote" in self.worker_specs
        if channel is None:
            if connect is not None or self.multihost:
                channel = "tcp"
            else:
                channel = "pipe" if start_method == "fork" else "spawn"
        if channel not in CHANNELS:
            raise ValueError(f"unknown channel {channel!r} "
                             f"(expected one of {CHANNELS})")
        if channel == "spawn" and start_method == "fork":
            start_method = "spawn"
        if channel == "pipe" and start_method != "fork":
            channel = "spawn"       # pipe wiring, spawn launch contract
        if self.multihost and channel != "tcp":
            raise ValueError("remote workers require channel='tcp'")
        if transport not in serde.TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r} "
                             f"(expected one of {serde.TRANSPORTS})")
        if self.multihost and transport not in serde.CROSS_HOST_TRANSPORTS:
            raise ValueError(
                f"transport {transport!r} is host-local and the worker pool "
                f"declares remote workers; pick one of "
                f"{serde.CROSS_HOST_TRANSPORTS}")
        self.start_method = start_method
        self.channel = channel
        self.n_workers = n_workers
        self.policy = policy
        self.worker_speed = list(worker_speed) if worker_speed else None
        self.pipeline_depth = max(1, pipeline_depth)
        self.outputs_only = outputs_only
        self.fail_worker = fail_worker
        self.join_after = join_after
        self.progress_timeout = progress_timeout
        self.seed = seed
        self.transport = transport
        self.transport_used: Optional[str] = None
        self.shm_threshold = max(1, shm_threshold)
        self.bandwidth = bandwidth
        self.token = token
        self.accept_timeout = accept_timeout
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        if speculate_after is not None and speculate_after <= 0:
            raise ValueError("speculate_after must be a positive "
                             "×expected-duration multiple (or None to "
                             "disable speculation)")
        self.speculate_after = speculate_after
        self.host = host_id()
        self.seg_prefix: Optional[str] = None    # last run's shm name prefix
        self.stats: Dict[str, int] = {}
        self.wall_time = 0.0
        self.recovery_events: List[Dict[str, Any]] = []
        # one entry per twin launched: {tid, primary, twin, t} — live during
        # the run (tests/chaos hooks poll it to aim a kill at the primary)
        self.speculation_events: List[Dict[str, Any]] = []
        self._commands: List[Tuple] = []
        self._cmd_lock = threading.Lock()
        # stats/recovery_events/wall_time are per-run instance attributes,
        # so one executor runs ONE graph at a time; concurrent submissions
        # queue on this lock (use separate executors for parallel jobs)
        self._run_lock = threading.Lock()
        self._active = False
        # the listener outlives runs: remote workers need a stable address
        # to dial before run() is even called
        self.listener: Optional[TcpListener] = None
        self.address: Optional[str] = None
        if channel == "tcp":
            self.listener = TcpListener(connect or "127.0.0.1:0",
                                        token=token)
            self.address = self.listener.address

    # ------------------------------------------------------------- frontend
    def run(self, graph: TaskGraph,
            inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
        return self._execute(graph, inputs)

    def submit(self, graph: TaskGraph,
               inputs: Optional[Dict[str, Any]] = None,
               label: str = "") -> ClusterFuture:
        """Async submission: returns immediately with a future; the run
        executes on a background driver thread with a fresh worker pool.
        Runs on the SAME executor serialize (stats are per-run) — use one
        executor per job for true inter-job concurrency."""
        fut = ClusterFuture(label)

        def drive() -> None:
            try:
                result, stats, wall = self._execute_with_stats(graph, inputs)
                fut._set_result(result, stats=stats, wall_time=wall)
            except BaseException as e:   # noqa: BLE001 — carried by future
                fut._set_error(e)

        threading.Thread(target=drive, daemon=True,
                         name=f"cluster-driver-{label or id(fut)}").start()
        return fut

    def add_worker(self) -> None:
        """Elastic join: grow the pool (mid-run if a run is active)."""
        with self._cmd_lock:
            if self._active:
                self._commands.append(("join",))
            else:
                self.n_workers += 1
                self.worker_specs.append("local")

    def kill_worker(self, wid: int) -> None:
        """Chaos hook: SIGKILL worker ``wid`` of the active run."""
        with self._cmd_lock:
            self._commands.append(("kill", wid))

    def close(self) -> None:
        """Release the executor's listening socket (TCP channel only)."""
        if self.listener is not None:
            self.listener.close()
            self.listener = None

    def __del__(self) -> None:      # pragma: no cover — GC timing
        try:
            self.close()
        except Exception:
            pass

    # -------------------------------------------------------------- driver
    def _execute(self, graph: TaskGraph,
                 inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        return self._execute_with_stats(graph, inputs)[0]

    def _execute_with_stats(self, graph: TaskGraph,
                            inputs: Optional[Dict[str, Any]]):
        """Run + a stats/wall_time snapshot taken while the run lock is
        still held — a queued submission on the same executor reassigns
        the per-run fields the moment the lock is released."""
        graph.validate()
        with self._run_lock:
            result = self._execute_locked(graph, inputs)
            return result, dict(self.stats), self.wall_time

    def _execute_locked(self, graph: TaskGraph,
                        inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        ctx = mp.get_context(self.start_method)
        transport = self.transport_used = serde.resolve_transport(
            self.transport, multihost=self.multihost)
        seg_prefix = self.seg_prefix = f"rr{os.getpid():x}" \
                                       f"{uuid.uuid4().hex[:8]}"
        peer_dir = (tempfile.mkdtemp(prefix="rrpeer")
                    if transport == "sock" else None)
        driver_namer = serde.SegmentNamer(f"{seg_prefix}d")
        stats = self.stats = {
            "dispatched": 0, "steals": 0, "transfers": 0, "recomputed": 0,
            "failures": 0, "joins": 0, "dropped": 0,
            "transfers_direct": 0, "transfers_driver": 0,
            "bytes_moved": 0, "bytes_driver": 0, "bytes_direct": 0,
            "n_speculative": 0, "speculative_wins": 0,
            "speculative_swept": 0, "speculative_wasted_s": 0.0,
        }
        self.recovery_events = []
        self.speculation_events = []
        t0 = time.perf_counter()

        store = DriverObjectStore(graph)
        workers: Dict[int, _Worker] = {}
        next_wid = 0
        listener = self.listener
        # graph shipped once per run to graph-less (remote) dialers
        graph_blob: List[Optional[bytes]] = [None]
        # handshaken dials not yet matched to the local proc that owns them
        dial_stash: List[Tuple[Any, dict]] = []

        def run_config(hello: dict) -> dict:
            # the address OTHER workers use to reach this worker's peer
            # data-plane server.  A local worker dials the driver over
            # loopback, so the IP the driver saw (127.x) is unroutable
            # from remote consumers — advertise this machine's real
            # interface instead when the pool spans hosts.
            # any TCP-listener run can gain cross-host joiners mid-run
            # (not just declared-remote pools), so the rewrite keys on
            # the data plane being TCP, not on self.multihost
            peer_ip = hello.get("peer_ip", "127.0.0.1")
            if listener is not None and transport == "tcp" \
                    and peer_ip.startswith("127."):
                peer_ip = routable_ip()
            return {
                "transport": transport,
                "shm_threshold": self.shm_threshold,
                "seg_prefix": seg_prefix,
                "peer_dir": peer_dir,
                "peer_host": peer_ip,
                "heartbeat_interval": self.heartbeat_interval,
                # the worker tolerates a longer driver silence than the
                # driver tolerates of it: the driver's loop always has
                # traffic to send, a worker mid-task may not
                "worker_heartbeat_timeout": max(self.heartbeat_timeout * 3,
                                                self.progress_timeout),
            }

        def ship_graph() -> bytes:
            if graph_blob[0] is None:
                try:
                    graph_blob[0] = pickle.dumps((graph, inputs), protocol=5)
                except Exception as e:
                    raise ValueError(
                        "graph is not picklable, so it cannot be shipped to "
                        "a remote worker that did not inherit it (use "
                        "module-level task functions, as with "
                        f"start_method='spawn'): {e!r}") from e
            return graph_blob[0]

        def adopt(sock, hello: dict, proc=None) -> _Worker:
            """Driver half of the TCP handshake: assign a wid, send the
            welcome (config + graph for graph-less workers), wrap the
            socket in a heartbeat-tracked channel."""
            nonlocal next_wid
            worker_host = hello.get("host", "?")
            if worker_host != self.host \
                    and transport not in serde.CROSS_HOST_TRANSPORTS:
                # a cross-host dial into a host-local data plane can never
                # resolve handles; refuse it with a reason, loudly
                msg = (f"worker on host {worker_host!r} cannot join a "
                       f"transport={transport!r} run (host-local data "
                       f"plane); use transport='tcp' or 'driver'")
                try:
                    from .channel import _send_frame
                    _send_frame(sock, pickle.dumps(("reject", msg),
                                                   protocol=5))
                except OSError:
                    pass
                try:
                    sock.close()
                except OSError:
                    pass
                raise ValueError(msg)
            try:
                blob = None if hello.get("has_graph") else ship_graph()
            except ValueError as e:
                try:
                    from .channel import _send_frame
                    _send_frame(sock, pickle.dumps(("reject", str(e)),
                                                   protocol=5))
                except OSError:
                    pass
                try:
                    sock.close()
                except OSError:
                    pass
                raise
            chan = TcpChannel(sock,
                              heartbeat_interval=self.heartbeat_interval,
                              heartbeat_timeout=self.heartbeat_timeout,
                              proc=proc)
            wid = next_wid
            next_wid += 1
            try:
                chan.send(("welcome", wid, run_config(hello), blob))
            except ChannelClosed as e:
                chan.close()
                raise TimeoutError(f"worker dial died during welcome: "
                                   f"{e}") from e
            w = _Worker(wid, chan, worker_host, proc=proc)
            workers[wid] = w
            store.add_worker(wid, host=worker_host)
            return w

        def heartbeat_all() -> None:
            """Keep already-adopted workers' driver-silence watchdogs fed
            while the driver is parked in an adoption barrier (the main
            loop isn't running yet, so nobody else sends)."""
            for w in workers.values():
                if w.alive:
                    w.chan.maybe_heartbeat()

        def adopt_dialer_for(proc) -> _Worker:
            """Match a handshaken dial to the local process we just
            started (by pid), stashing unrelated dials (remote workers
            arriving early) for later adoption."""
            assert listener is not None
            for i, (sock, hello) in enumerate(dial_stash):
                if hello.get("pid") == proc.pid:
                    dial_stash.pop(i)
                    return adopt(sock, hello, proc=proc)
            deadline = time.monotonic() + self.accept_timeout
            while True:
                if not proc.is_alive():
                    # a dialer that died at bootstrap (import error, OOM)
                    # will never dial: fail now with the real cause, not
                    # after a silent accept_timeout hang
                    raise RuntimeError(
                        f"local worker (pid {proc.pid}) exited with code "
                        f"{proc.exitcode} before dialing {self.address}")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"local worker pid {proc.pid} never dialed "
                        f"{self.address} within {self.accept_timeout}s")
                heartbeat_all()
                try:
                    sock, hello = listener.get_worker(min(0.5, remaining))
                except TimeoutError:
                    continue        # re-check the dialer's pulse
                if hello.get("pid") == proc.pid:
                    return adopt(sock, hello, proc=proc)
                dial_stash.append((sock, hello))

        def spawn() -> _Worker:
            """Start one local worker on the configured channel family."""
            nonlocal next_wid
            if self.channel == "tcp":
                proc = ctx.Process(
                    target=tcp_worker_main, args=(self.address,),
                    kwargs=({"token": self.token, "graph": graph,
                             "inputs": inputs}
                            if self.start_method == "fork"
                            else {"token": self.token}),
                    daemon=True, name="cluster-worker-dialer")
                proc.start()
                return adopt_dialer_for(proc)
            wid = next_wid
            next_wid += 1
            parent, child = ctx.Pipe(duplex=True)
            proc = ctx.Process(target=pipe_worker_main,
                               args=(wid, child, graph, inputs, transport,
                                     self.shm_threshold, seg_prefix,
                                     peer_dir),
                               daemon=True, name=f"cluster-worker-{wid}")
            proc.start()
            child.close()
            cls = PipeChannel if self.channel == "pipe" else SpawnChannel
            w = _Worker(wid, cls(parent, proc), self.host, proc=proc)
            workers[wid] = w
            store.add_worker(wid, host=self.host)
            return w

        def adopt_remote() -> _Worker:
            """Fill one declared ``remote`` slot from the dial queue."""
            assert listener is not None
            if dial_stash:
                sock, hello = dial_stash.pop(0)
                return adopt(sock, hello, proc=None)
            deadline = time.monotonic() + self.accept_timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"no worker dialed {self.address} within "
                        f"{self.accept_timeout}s (start workers with: "
                        f"python -m repro.launch.remote --connect "
                        f"{self.address})")
                heartbeat_all()     # earlier adoptees must not starve
                try:
                    sock, hello = listener.get_worker(min(0.5, remaining))
                except TimeoutError:
                    continue
                return adopt(sock, hello, proc=None)

        rank = graph.critical_path_rank()
        succ = store.successors
        n_total = len(graph.nodes)
        required = (set(graph.outputs) if self.outputs_only
                    else set(graph.nodes))

        state: Dict[int, int] = {}
        for tid, node in graph.nodes.items():
            state[tid] = READY if not node.all_deps else PENDING
        done: Set[int] = set()
        finish_times: Dict[int, float] = {}
        # tid -> (wid, still-missing dep tids) for transfer-blocked dispatches
        waiting: Dict[int, Tuple[int, Set[int]]] = {}
        fetching: Dict[int, int] = {}    # dep tid -> wid the fetch went to
        # -- speculation state: a task may run on SEVERAL workers at once --
        runners: Dict[int, Set[int]] = {}         # tid -> wids running it now
        run_started: Dict[int, Dict[int, float]] = {}  # tid -> wid -> t_start
        spec_twins: Dict[int, Set[int]] = {}      # tid -> speculative wids
        # expected durations: static plan hint (cost units), calibrated to
        # seconds by an EWMA of actual/planned — same 0.9/0.1 blend the
        # launchers' straggler detector uses
        planned_dur: Dict[int, float] = {
            t: max(n.cost, 1e-6) for t, n in graph.nodes.items()}
        ewma_ratio: Optional[float] = None  # seconds per cost unit; None
        # until the first completion — no speculation before calibration
        error: List[BaseException] = []
        join_after = self.join_after     # consumed per run, not per executor
        last_progress = time.perf_counter()

        def alive_ids() -> List[int]:
            return [w.wid for w in workers.values() if w.alive]

        def speeds_for(wids: List[int]) -> Optional[List[float]]:
            if self.worker_speed is None:
                return None
            return [self.worker_speed[w % len(self.worker_speed)]
                    for w in wids]

        def hosts_for(wids: List[int]) -> List[str]:
            return [workers[w].host for w in wids]

        def alive_owner(tid: int) -> Optional[int]:
            return next((x for x in store.locations(tid)
                         if x in workers and workers[x].alive), None)

        # planned placement: schedule slot i -> i-th alive worker id
        plan_worker: Dict[int, int] = {}

        def make_plan(initial: bool) -> None:
            wids = alive_ids()
            if not wids:
                return
            try:
                if initial:
                    sched = list_schedule(
                        graph, len(wids), policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed,
                        worker_host=hosts_for(wids))
                else:
                    # replanning mid-run knows value sizes and current
                    # placements: make the comm-cost term real so the new
                    # plan keeps consumers next to the bytes they need —
                    # and, via worker_host, on the right machine
                    placed = {}
                    for t in finish_times:
                        ow = alive_owner(t)
                        if ow is not None:
                            placed[t] = wids.index(ow)
                    sched = replan(
                        graph, dict(finish_times), len(wids),
                        now=time.perf_counter() - t0, policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed,
                        data_sizes=dict(store.sizes),
                        bandwidth=self.bandwidth, placed=placed,
                        worker_host=hosts_for(wids))
            except Exception:            # plan is advisory; never fatal
                plan_worker.clear()
                return
            plan_worker.clear()
            for tid, p in sched.placements.items():
                plan_worker[tid] = wids[p.worker]
            # static cost-model hint for the speculation overdue test
            # (node.cost is the pre-plan fallback)
            for tid, dur in sched.expected_durations().items():
                planned_dur[tid] = max(dur, 1e-6)

        # ---------------------------------------------------------- helpers
        def safe_send(w: _Worker, msg: tuple) -> bool:
            """Send to a worker; an already-dead peer (organic SIGKILL, OOM,
            segfault, socket reset, backpressure overflow) becomes a
            failure-handled event, never an exception out of the driver
            loop."""
            try:
                w.chan.send(msg)
                return True
            except ChannelClosed:
                on_worker_death(w)
                return False

        def account_pipe(handle: serde.Handle) -> None:
            n = serde.pipe_nbytes(handle)
            stats["bytes_driver"] += n
            stats["bytes_moved"] += n

        def account_transfer(handle: serde.Handle) -> None:
            p, d = serde.pipe_nbytes(handle), serde.direct_nbytes(handle)
            stats["bytes_driver"] += p
            stats["bytes_direct"] += d
            stats["bytes_moved"] += p + d
            if d > 0:
                stats["transfers_direct"] += 1
            else:
                stats["transfers_driver"] += 1
            stats["transfers"] += 1

        def publish_cached(d: int) -> Optional[serde.Handle]:
            """Encode a driver-cached value for shipping; a value that
            cannot be serialized is a task error, not a worker death."""
            try:
                h = serde.encode(store.cache[d], transport=transport,
                                 threshold=self.shm_threshold,
                                 namer=driver_namer)
            except Exception as e:      # noqa: BLE001 — surfaced on future
                error.append(TaskFailed(
                    d, graph.nodes[d].name,
                    RuntimeError(f"SerializationError: result of task {d} "
                                 f"cannot be shipped to a worker: {e!r}")))
                return None
            store.set_handle(d, h)
            return h

        def build_extra(tid: int, wid: int
                        ) -> Tuple[Optional[Dict[int, Any]], Set[int]]:
            """Transfer handles for every input of ``tid`` not already
            replicated on ``wid``; the missing set needs fetches first.
            Returns (None, _) when a value failed to serialize (error set)."""
            extra: Dict[int, Any] = {}
            missing: Set[int] = set()
            for d in graph.nodes[tid].all_deps:
                if store.has_replica(d, wid):
                    continue                   # already local
                h = store.handles.get(d)
                if h is None and d in store.cache:
                    h = publish_cached(d)
                    if h is None:
                        return None, missing
                if h is not None:
                    extra[d] = h
                else:
                    missing.add(d)
            return extra, missing

        def move_cost(tid: int, wid: int) -> int:
            """Bytes-weighted cost of running ``tid`` on ``wid``.  A
            published value costs half (one consumer-side materialization);
            an unpublished remote value costs its full size (publish +
            materialize) — and every byte whose nearest copy lives on
            another *host* counts double, so the stealing loop prefers
            same-host shm moves over cross-host TCP pulls."""
            host = workers[wid].host
            cost = 0
            for d in graph.nodes[tid].all_deps:
                if store.has_replica(d, wid):
                    continue
                size = store.sizes.get(d, 0)
                if d in store.handles or d in store.cache:
                    c = size // 2
                else:
                    c = size
                if not store.on_host(d, host) and d not in store.cache:
                    c *= 2          # nearest copy is on another machine
                cost += c
            return cost

        def try_dispatch(tid: int, w: _Worker) -> bool:
            """Assign READY task ``tid`` to worker ``w``; ship handles or
            request publication of whatever remote inputs it needs.
            Returns False when a recovery ran underneath (caller must
            re-snapshot the ready set)."""
            extra, missing = build_extra(tid, w.wid)
            if extra is None:
                return False                    # serialization task error
            if missing:
                # a "done" dep with no live owner and no durable copy is a
                # lost value the death handler didn't see (e.g. GC raced a
                # transfer): recover it through lineage like any other loss
                unreachable = {
                    d for d in missing
                    if d not in fetching and alive_owner(d) is None}
                if unreachable:
                    state[tid] = READY
                    recompute_lost(unreachable, unreachable, None)
                    return False
                state[tid] = WAITING
                waiting[tid] = (w.wid, missing)
                w.assigned.add(tid)
                for d in missing:
                    if d not in fetching:
                        ow = alive_owner(d)
                        if ow is None or \
                                not safe_send(workers[ow], ("fetch", d)):
                            # the owner died under this loop.  If the dep
                            # survives on a replica the death handler has
                            # no record of THIS waiter (fetching[d] was
                            # never set) — unwind to READY so dispatch
                            # retries against the survivors, instead of
                            # stranding the task in WAITING forever.
                            if waiting.pop(tid, None) is not None:
                                w.assigned.discard(tid)
                            if state.get(tid) == WAITING:
                                state[tid] = READY
                            return False
                        fetching[d] = ow
                return True
            return launch(tid, w, extra)

        def launch(tid: int, w: _Worker, extra: Dict[int, Any],
                   speculative: bool = False) -> bool:
            """Ship the run message; False when the worker died under the
            send (the death handler has already reset ``tid`` to READY —
            or left it INFLIGHT when another runner survives)."""
            state[tid] = INFLIGHT
            w.inflight.add(tid)
            runners.setdefault(tid, set()).add(w.wid)
            run_started.setdefault(tid, {})[w.wid] = time.perf_counter()
            if speculative:
                spec_twins.setdefault(tid, set()).add(w.wid)
            if not safe_send(w, ("run", tid, extra)):
                return False
            stats["dispatched"] += 1
            if speculative:
                stats["n_speculative"] += 1
            for h in extra.values():
                account_transfer(h)
            return True

        def finish_waiting(tid: int) -> None:
            """All transfers for a WAITING task arrived — launch it."""
            wid, _ = waiting.pop(tid)
            w = workers[wid]
            w.assigned.discard(tid)
            if not w.alive:
                state[tid] = READY
                return
            extra, missing = build_extra(tid, wid)
            if extra is None:
                return                  # serialization task error
            if missing:                 # a handle vanished under us (GC /
                state[tid] = READY      # racing recovery): re-dispatch
                return
            launch(tid, w, extra)

        def stealable(tid: int) -> bool:
            """A task may run off-plan only when its planned home cannot
            take it now (dead, or pipeline full) — stealing exists for
            stragglers, not for letting the first worker vacuum the whole
            ready set before its peers get a dispatch turn."""
            ow = plan_worker.get(tid)
            if ow is None or ow not in workers:
                return True
            home = workers[ow]
            return not home.alive or home.load() >= self.pipeline_depth

        def dispatch() -> None:
            ready = [t for t, s in state.items() if s == READY]
            if not ready:
                return
            ready.sort(key=lambda t: (-rank[t], t))
            for w in list(workers.values()):
                if not w.alive:
                    continue
                while w.load() < self.pipeline_depth and ready:
                    # locality-aware choice: among this worker's planned
                    # tasks (or, stealing, the stealable ready window) run
                    # the one needing the fewest remote input bytes
                    window = ready[:32]
                    planned = [t for t in window
                               if plan_worker.get(t, w.wid) == w.wid]
                    pool = planned or [t for t in window if stealable(t)]
                    if not pool:
                        break       # everything here belongs to live peers
                    mine = min(pool, key=lambda t: (move_cost(t, w.wid),
                                                    -rank[t], t))
                    if not planned:
                        stats["steals"] += 1   # off-plan work
                    ready.remove(mine)
                    if state.get(mine) != READY:
                        continue    # demoted since the snapshot
                    if not try_dispatch(mine, w):
                        return      # recovery invalidated the snapshot

        def maybe_gc(tid: int) -> None:
            if not self.outputs_only or not store.collectable(tid):
                return
            for wid in list(store.locations(tid)):
                if wid in workers and workers[wid].alive:
                    safe_send(workers[wid], ("drop", [tid]))
            store.invalidate({tid})     # also unlinks its shm segments
            store.mark_dropped(tid)     # late duplicate publishes: sweep
            stats["dropped"] += 1

        def runner_gone(tid: int, wid: int) -> Optional[float]:
            """Bookkeeping when ``wid`` stops running ``tid`` (done,
            cancelled, deplost, or death).  Returns its dispatch time."""
            rs = runners.get(tid)
            if rs is not None:
                rs.discard(wid)
                if not rs:
                    runners.pop(tid, None)
            starts = run_started.get(tid)
            st = starts.pop(wid, None) if starts else None
            if starts is not None and not starts:
                run_started.pop(tid, None)
            return st

        def still_running(tid: int) -> bool:
            """True while a live worker is (believed to be) executing
            ``tid`` — dead runners were already discarded by their death
            handler, but guard against re-entrancy mid-handling."""
            return any(x in workers and workers[x].alive
                       for x in runners.get(tid, ()))

        def on_done(w: _Worker, tid: int, wall: float, nbytes: int,
                    replicated: Sequence[int]) -> None:
            nonlocal last_progress, ewma_ratio
            last_progress = time.perf_counter()
            w.inflight.discard(tid)
            runner_gone(tid, w.wid)
            if state.get(tid) == DONE:
                # late duplicate: a speculation loser that kept executing
                # after the winner, or a replay raced by recovery.  Purity
                # makes the value identical, so each publish (the result
                # AND the transfer inputs the loser materialized) either
                # reconciles as a legitimate extra replica or — when the
                # GC already swept that value — is swept on this worker
                # too (it must not hold a value the driver thinks is gone
                # everywhere)
                sweep: List[int] = []
                if store.was_dropped(tid):
                    sweep.append(tid)
                    stats["speculative_swept"] += 1
                else:
                    store.record_replica(tid, w.wid)
                for d in replicated:
                    if state.get(d) != DONE:
                        continue
                    if store.was_dropped(d):
                        sweep.append(d)
                    else:
                        store.record_replica(d, w.wid)
                if sweep and w.alive:
                    safe_send(w, ("drop", sweep))
                stats["speculative_wasted_s"] += wall
                return
            # record transfer replicas first, so GC drops reach them too;
            # skip deps a racing recovery has invalidated (stale-but-pure
            # copies are harmless, but must not resurrect tracking state)
            for d in replicated:
                if state.get(d) == DONE:
                    store.record_replica(d, w.wid)
            state[tid] = DONE
            done.add(tid)
            finish_times[tid] = time.perf_counter() - t0
            store.record(tid, w.wid, nbytes)
            w.n_done += 1
            # runtime calibration of the static cost model (the launchers'
            # 0.9/0.1 straggler EWMA): seconds of wall per planned cost unit
            ratio = wall / planned_dur.get(tid, 1.0)
            ewma_ratio = (ratio if ewma_ratio is None
                          else 0.9 * ewma_ratio + 0.1 * ratio)
            # winner election: this completion wins; every other runner of
            # tid gets an idempotent cancel (honored between tasks — one
            # mid-task keeps going and late-dones into the branch above)
            if tid in spec_twins:
                if w.wid in spec_twins[tid]:
                    stats["speculative_wins"] += 1
                spec_twins.pop(tid, None)
            for owid in sorted(runners.get(tid, ())):
                ow = workers.get(owid)
                if ow is not None and ow.alive:
                    safe_send(ow, ("cancel", tid))
            for d in graph.nodes[tid].all_deps:
                store.consumed(d)
                maybe_gc(d)
            for s in succ[tid]:
                if state[s] == PENDING and \
                        all(state[d] == DONE for d in graph.nodes[s].all_deps):
                    state[s] = READY
            if self.fail_worker and w.wid == self.fail_worker[0] \
                    and w.n_done >= self.fail_worker[1] and w.alive:
                kill(w)
            nonlocal join_after
            if join_after and len(done) >= join_after[0]:
                n_new, join_after = join_after[1], None
                for _ in range(n_new):
                    join_one()

        def kill(w: _Worker) -> None:
            """SIGKILL + immediate failure handling (used by injection and
            the kill_worker command; organic deaths arrive via the
            channel).  A remote worker has no local pid to signal, so it
            is told to ``die`` — the executioner's message, then the same
            death handling."""
            if w.proc is not None:
                try:
                    os.kill(w.proc.pid, signal.SIGKILL)
                    w.proc.join(timeout=5.0)
                except (ProcessLookupError, OSError):
                    pass
            else:
                try:
                    w.chan.send(("die",))
                except ChannelClosed:
                    pass
            on_worker_death(w)

        def join_one(adopted: Optional[_Worker] = None) -> _Worker:
            w = adopted if adopted is not None else spawn()
            stats["joins"] += 1
            make_plan(initial=False)
            return w

        def recompute_lost(needed: Set[int], lost: Set[int],
                           cause: Any) -> None:
            """Lineage recovery: schedule the minimal recompute set for
            ``needed`` lost values, then replan onto the live workers."""
            available = store.available(set(alive_ids()))
            plan = recovery_plan(graph, needed, available)
            stats["recomputed"] += len(plan)
            self.recovery_events.append({
                "worker": cause, "lost": set(lost), "needed": set(needed),
                "available": set(available), "plan": set(plan),
            })

            will_run = plan | {t for t, s in state.items() if s != DONE}
            store.invalidate(plan)
            store.reset_consumers(plan, will_run)
            for t in plan:                  # deps outside the plan get re-read
                for d in graph.nodes[t].all_deps:
                    if d not in plan:
                        store.consumers_left[d] = \
                            store.consumers_left.get(d, 0) + 1
            for t in plan:
                done.discard(t)
                finish_times.pop(t, None)
                # a recomputed incarnation starts fresh: old twin identity
                # must not misattribute its completion as a speculative win
                spec_twins.pop(t, None)
            # WAITING tasks elsewhere may block on a lost value: reset them
            for tid in list(waiting):
                wid, need = waiting[tid]
                if need & plan:
                    waiting.pop(tid)
                    workers[wid].assigned.discard(tid)
                    state[tid] = READY
            for t in plan:
                state[t] = (READY if all(state[d] == DONE
                                         for d in graph.nodes[t].all_deps)
                            else PENDING)
            # demote READY tasks whose deps just un-completed
            for tid, s in list(state.items()):
                if s == READY and any(state[d] != DONE
                                      for d in graph.nodes[tid].all_deps):
                    state[tid] = PENDING

            if not alive_ids():
                error.append(RuntimeError(
                    "cluster lost every worker; cannot recover"))
                return
            make_plan(initial=False)       # replan onto the survivors

        def on_worker_death(w: _Worker) -> None:
            nonlocal last_progress
            if not w.alive:
                return
            last_progress = time.perf_counter()
            w.alive = False
            w.chan.close()
            stats["failures"] += 1

            # tasks that never completed there simply go back in the pool —
            # with two speculation exceptions: a SIGKILL of the original
            # while a twin still runs must NOT re-queue (the survivor owns
            # the task; re-queueing would be a double recovery), and a
            # loser that died while running an already-DONE task is just
            # wasted work, accounted and forgotten
            death_t = time.perf_counter()
            for tid in list(w.inflight):
                st = runner_gone(tid, w.wid)
                if state.get(tid) == DONE:
                    if st is not None:
                        stats["speculative_wasted_s"] += death_t - st
                    continue
                if still_running(tid):
                    continue            # a live twin/original has it
                state[tid] = READY
            w.inflight.clear()
            for tid in list(w.assigned):
                waiting.pop(tid, None)
                state[tid] = READY
            w.assigned.clear()

            # values whose LAST copy lived in its store are lost -> lineage
            # (replicas / shm-published handles / driver cache survive)
            lost = store.drop_worker(w.wid)
            # fetches sent to the dead worker never reply: re-aim them at a
            # surviving replica, or let the recovery below reset the waiters
            for d, target in list(fetching.items()):
                if target != w.wid:
                    continue
                fetching.pop(d, None)
                if d in lost:
                    continue               # recovery resets its waiters
                ow = alive_owner(d)
                if ow is not None and safe_send(workers[ow], ("fetch", d)):
                    fetching[d] = ow
            if self.outputs_only:
                needed = {t for t in lost
                          if t in graph.outputs
                          or store.consumers_left.get(t, 0) > 0}
            else:
                needed = set(lost)
            recompute_lost(needed, lost, w.wid)

        def on_value(w: _Worker, tid: int, found: bool, handle: Any) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            fetching.pop(tid, None)
            if not found:
                # owner dropped/lost it between request and reply; try a
                # surviving replica, else recover like a partial failure
                if state.get(tid) == DONE and not store.durable(tid):
                    ow = alive_owner(tid)
                    if ow is not None:
                        if safe_send(workers[ow], ("fetch", tid)):
                            fetching[tid] = ow
                        return
                    store.invalidate({tid})
                    recompute_lost({tid}, {tid}, None)
                return
            if state.get(tid) != DONE:
                # a recovery invalidated tid while this reply was in flight:
                # the recompute supersedes it; free the stale segments
                serde.release(handle)
                return
            account_pipe(handle)
            store.set_handle(tid, handle)
            for t in list(waiting):
                entry = waiting.get(t)
                if entry is None:     # popped by a recovery mid-loop
                    continue
                _, need = entry
                need.discard(tid)
                if not need:
                    finish_waiting(t)

        def on_deplost(w: _Worker, tid: int, deps: Sequence[int]) -> None:
            """A dispatched task's input handles would not resolve (owner
            died mid-transfer / GC raced): re-queue the task and recover
            any input that is genuinely gone."""
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(tid)
            runner_gone(tid, w.wid)
            if state.get(tid) == DONE:
                # a speculation loser lost the race to the winner AND its
                # input handles to the winner-triggered GC sweep: nothing
                # is actually lost (a dep a live consumer still needs
                # surfaces through that consumer's own fetch/deplost)
                return
            if state.get(tid) == INFLIGHT and not still_running(tid):
                state[tid] = READY
            bad = {d for d in deps
                   if state.get(d) == DONE and not store.durable(d)
                   and alive_owner(d) is None}
            if bad:
                store.invalidate(bad)
                recompute_lost(bad, bad, None)
            # inputs may themselves be mid-recompute (an earlier recovery):
            # wait for them instead of re-triggering loss detection
            if state.get(tid) == READY and any(
                    state.get(d) != DONE
                    for d in graph.nodes[tid].all_deps):
                state[tid] = PENDING

        def on_cancelled(w: _Worker, tid: int) -> None:
            """The worker skipped a queued run of ``tid`` under a cancel
            mark.  Normally the winner already completed (nothing to do);
            if the mark was stale — a lineage-recovery re-dispatch raced a
            cancel from a previous incarnation — the run was still wanted,
            so the task goes back in the pool."""
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(tid)
            runner_gone(tid, w.wid)
            if state.get(tid) == INFLIGHT and not still_running(tid):
                state[tid] = READY

        def maybe_speculate() -> None:
            """Speculative re-execution of stragglers: duplicate the
            most-overdue running task onto an idle worker.  Runs only when
            no READY work exists anywhere (twins never displace first
            executions) and only after the first completion calibrated the
            cost model into seconds.  The pick itself is
            :func:`repro.core.simulator.pick_speculation` — the simulator's
            policy, verbatim."""
            if self.speculate_after is None or ewma_ratio is None:
                return
            if any(s == READY for s in state.values()):
                return
            idle = [w for w in workers.values()
                    if w.alive and w.load() == 0]
            if not idle:
                return
            now = time.perf_counter()
            overdue_view: Dict[int, Tuple[float, float]] = {}
            for tid, wids in runners.items():
                if state.get(tid) != INFLIGHT or len(wids) != 1:
                    continue                # done, or already twinned
                (rw,) = tuple(wids)
                st = run_started.get(tid, {}).get(rw)
                if st is None:
                    continue
                expected = planned_dur.get(tid, 1.0) * ewma_ratio
                overdue_view[tid] = (now - st, max(expected, 1e-9))
            for w in idle:
                while overdue_view:
                    tid = pick_speculation(overdue_view,
                                           self.speculate_after)
                    if tid is None:
                        return
                    elapsed, _ = overdue_view.pop(tid)
                    extra, missing = build_extra(tid, w.wid)
                    if extra is None:
                        return              # serialization error surfaced
                    if missing:
                        continue            # inputs not shippable now; a
                        # twin is opportunistic — never fetch-block for one
                    primary = next(iter(runners.get(tid, {-1})))
                    self.speculation_events.append(
                        {"tid": tid, "primary": primary, "twin": w.wid,
                         "t": now - t0, "elapsed": elapsed})
                    if not launch(tid, w, extra, speculative=True):
                        return              # death handler ran underneath
                    break                   # one twin per idle worker

        def handle_msg(w: _Worker, msg: tuple) -> None:
            verb = msg[0]
            if verb == "done":
                on_done(w, msg[2], msg[3], msg[4], msg[5])
            elif verb == "value":
                on_value(w, msg[2], msg[3], msg[4])
            elif verb == "deplost":
                on_deplost(w, msg[2], msg[3])
            elif verb == "cancelled":
                on_cancelled(w, msg[2])
            elif verb == "error":
                tid = msg[2]
                w.inflight.discard(tid)
                was_runner = w.wid in runners.get(tid, ())
                runner_gone(tid, w.wid)
                if msg[3] == "MissingInput":
                    # caller-error contract: never wrapped in TaskFailed
                    error.append(MissingInput(msg[4]))
                elif state.get(tid) == DONE and was_runner:
                    # a speculation loser failing AFTER the winner (e.g.
                    # its inputs were GC-swept under the race) must not
                    # abort a run whose result already exists.  Only
                    # *execution* duplicates qualify — a fetch-reply
                    # serialization error on a DONE task is still fatal
                    # (the value cannot be collected)
                    pass
                else:
                    node = graph.nodes.get(tid)
                    error.append(TaskFailed(
                        tid, node.name if node else f"#{tid}",
                        RuntimeError(f"{msg[3]}: {msg[4]}")))
            elif verb in ("hb", "bye"):
                pass        # liveness bookkeeping happens in the channel

        def pump(timeout: float) -> None:
            chans = {w.chan.selectable(): w
                     for w in workers.values() if w.alive}
            if not chans:
                return
            for sel in conn_wait(list(chans), timeout=timeout):
                w = chans[sel]
                try:
                    msgs = w.chan.recv_available()
                except ChannelClosed:
                    on_worker_death(w)
                    continue
                for msg in msgs:
                    if not w.alive:
                        break       # death handler ran under an earlier msg
                    handle_msg(w, msg)

        def collect_finals() -> bool:
            """All tasks done: materialize ``required`` values into the
            driver cache — decoding published handles directly (no control
            traffic), fetching handles for the rest.  Returns True when
            everything required is cached."""
            nonlocal last_progress
            missing = [t for t in required if t not in store.cache]
            if not missing:
                return True
            for t in missing:
                h = store.handles.get(t)
                if h is not None:
                    try:
                        value = serde.resolve(h)
                    except serde.TransferLost:
                        store.invalidate({t})
                        recompute_lost({t}, {t}, None)
                        return False
                    store.cache_value(t, value)
                    d = serde.direct_nbytes(h)
                    if d > 0:
                        stats["bytes_direct"] += d
                        stats["bytes_moved"] += d
                        stats["transfers_direct"] += 1
                    last_progress = time.perf_counter()
                    continue
                if t in fetching:
                    continue
                ow = alive_owner(t)
                if ow is None:
                    store.invalidate({t})
                    recompute_lost({t}, {t}, None)
                    return False
                if not safe_send(workers[ow], ("fetch", t)):
                    return False        # recovery ran; resume main loop
                fetching[t] = ow
            return not [t for t in required if t not in store.cache]

        def check_commands() -> None:
            with self._cmd_lock:
                cmds, self._commands = self._commands, []
            for cmd in cmds:
                if cmd[0] == "join":
                    join_one()
                elif cmd[0] == "kill" and cmd[1] in workers \
                        and workers[cmd[1]].alive:
                    kill(workers[cmd[1]])
            # a repro-worker dialing a live TCP run is an elastic join —
            # including dials parked in the stash while adopt_dialer_for
            # was pid-matching a local spawn (they would otherwise hang
            # unanswered until their handshake timeout)
            if listener is not None:
                while True:
                    pair = dial_stash.pop(0) if dial_stash \
                        else listener.poll_worker()
                    if pair is None:
                        break
                    try:
                        join_one(adopt(pair[0], pair[1], proc=None))
                    except (ValueError, TimeoutError):
                        pass    # cross-host dial into a host-local
                        # transport, or the dialer died mid-welcome:
                        # a bad joiner must never take down the run

        def check_deaths() -> None:
            """Channel-based liveness: the OS truth for pipe workers
            (``proc.is_alive``), missed heartbeats for TCP workers —
            socket death delivers no SIGCHLD, so the *channel* is the
            only witness."""
            for w in list(workers.values()):
                if w.alive and w.chan.dead() is not None:
                    on_worker_death(w)

        # ------------------------------------------------------- main loop
        self._active = True
        try:
            for spec in self.worker_specs:
                if spec == "remote":
                    adopt_remote()
                else:
                    spawn()
            make_plan(initial=True)
            while not error:
                check_commands()
                if len(done) >= n_total:
                    if collect_finals():
                        break
                else:
                    dispatch()
                    maybe_speculate()
                pump(timeout=0.02)
                check_deaths()
                for w in workers.values():
                    if w.alive:
                        w.chan.maybe_heartbeat()
                if time.perf_counter() - last_progress > self.progress_timeout:
                    by_state: Dict[int, List[int]] = {}
                    for t, s in state.items():
                        by_state.setdefault(s, []).append(t)
                    error.append(RuntimeError(
                        f"cluster made no progress for "
                        f"{self.progress_timeout}s "
                        f"(done {len(done)}/{n_total}, states "
                        f"{ {s: sorted(ts)[:8] for s, ts in by_state.items() if s != DONE} }, "
                        f"waiting {dict(list(waiting.items())[:4])}, "
                        f"fetching {dict(list(fetching.items())[:8])}, "
                        f"inflight {[sorted(w.inflight) for w in workers.values()]})"))
        finally:
            self._active = False
            # speculation losers still executing at shutdown burned their
            # time just the same — charge what the run observed of it
            end_t = time.perf_counter()
            for tid, starts in run_started.items():
                if state.get(tid) == DONE:
                    for st in starts.values():
                        stats["speculative_wasted_s"] += end_t - st
            for w in workers.values():
                if w.alive:
                    try:
                        w.chan.send(("stop",))
                    except ChannelClosed:
                        pass
            for w in workers.values():
                if w.proc is not None:
                    w.proc.join(timeout=5.0)
                    if w.proc.is_alive():
                        w.proc.terminate()
                        w.proc.join(timeout=5.0)
                w.chan.close()
            for sock, _ in dial_stash:      # dials we never adopted
                try:
                    sock.close()
                except OSError:
                    pass
            # hygiene sweep: free tracked handles, then clear the run's
            # /dev/shm prefix AND its peer-socket tmpdir — orphans from
            # workers killed mid-publish never cleaned up after themselves
            store.release_all()
            serde.sweep_segments(seg_prefix)
            serde.sweep_peer_sockets(peer_dir)
            self.wall_time = time.perf_counter() - t0

        if error:
            raise error[0]
        return {t: store.cache[t] for t in required}
