"""ClusterExecutor — the multi-process distributed runtime.

This is the paper's driver/worker architecture made real on one host:
OS-process workers (the stand-in for cluster nodes — same protocol, a
socket transport is a drop-in follow-up), a driver that schedules ready
tasks onto them, a driver-side :class:`DriverObjectStore` tracking where
every result lives, and lineage-based recovery when a worker dies.

Design points (mirroring the Haskell#/Cloud-Haskell driver designs and the
mapping-decision framing of Mapple):

* **Static plan, dynamic execution.**  ``scheduler.list_schedule`` produces
  a placement hint (critical-path priority, earliest-finish-time worker);
  the driver follows it opportunistically and *steals* — dispatches a ready
  task to an idle worker that wasn't its planned home — whenever the plan
  goes stale.  Both the plan (via ``data_sizes``/``placed`` comm costs in
  the scheduler) and the stealing choice (via a transfer-cost score over
  per-value sizes recorded at completion) are **locality-aware**: work
  prefers the worker already holding the largest share of its input bytes.
* **Zero-copy data plane.**  Cross-worker values move as *handles*
  (:mod:`repro.cluster.serde`): the owner publishes the payload once into
  a ``multiprocessing.shared_memory`` segment (or serves it over its unix
  socket when shm is unavailable), and the consumer maps/pulls it
  directly.  The driver pipe carries only control messages and handles —
  ``stats["bytes_driver"]`` vs ``stats["bytes_direct"]`` make the split
  observable; ``transport="driver"`` restores the PR-1 relay for A/B runs.
* **Pipelined dispatch.**  Up to ``pipeline_depth`` tasks are in a worker's
  pipe at once, so the driver overlaps dispatch/transfer with execution
  (the futures-style async core of ``submit``/``gather``).
* **Replicas, not broadcast.**  Results stay in the producing worker's
  local store; a transfer leaves the consumer holding a replica (tracked
  per-value as a *set* of holders), so later consumers read locally and a
  value is only lost when its last holder dies without a durable handle.
* **Lineage fault tolerance.**  On worker death the lost set is exactly
  the values with no surviving replica, no shm-published handle, and no
  driver-cached copy; ``lineage.recovery_plan`` gives the minimal
  recompute set (walking past GC'd ancestors in ``outputs_only`` runs),
  ``scheduler.replan`` re-places the remaining work on the survivors, and
  ``stats["recomputed"]`` counts exactly ``len(plan)``.  A SIGKILL
  mid-transfer degrades the same way: consumers that already hold a stale
  handle report ``deplost`` and the task re-queues behind the recovery.
* **Elasticity.**  ``add_worker()`` forks a fresh worker mid-run and
  replans onto the grown pool.
* **Segment hygiene.**  The driver is the single unlink authority:
  handles are released when the ``consumers_left`` GC drains a value
  (``outputs_only`` runs unlink eagerly), and a run-scoped ``/dev/shm``
  sweep in the shutdown path catches orphans from workers killed
  mid-publish.  No segment survives executor shutdown.

Failure injection for tests/benchmarks: ``fail_worker=(wid, n)`` SIGKILLs
worker ``wid`` after it completes ``n`` tasks; ``join_after=(n, k)`` forks
``k`` extra workers once ``n`` tasks have completed cluster-wide.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import shutil
import signal
import tempfile
import threading
import time
import uuid
from dataclasses import dataclass, field
from multiprocessing.connection import wait as conn_wait
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.executor import MissingInput, TaskFailed
from repro.core.graph import TaskGraph
from repro.core.lineage import recovery_plan
from repro.core.scheduler import list_schedule, replan

from . import serde
from .futures import ClusterFuture
from .objectstore import DriverObjectStore
from .worker import worker_main

PENDING, READY, WAITING, INFLIGHT, DONE = range(5)


@dataclass
class _Worker:
    wid: int
    proc: Any
    conn: Any
    alive: bool = True
    inflight: Set[int] = field(default_factory=set)   # run sent, not done
    assigned: Set[int] = field(default_factory=set)   # waiting on transfers
    n_done: int = 0

    def load(self) -> int:
        return len(self.inflight) + len(self.assigned)


class ClusterExecutor:
    """Executes a :class:`TaskGraph` on ``n_workers`` forked processes.

    Satisfies the :class:`repro.core.executor.Executor` protocol — results
    are bit-identical to :func:`repro.core.executor.execute_sequential`
    because tasks are pure and the value tables are exact.

    ``transport`` selects the data plane: ``"shm"`` (zero-copy shared
    memory), ``"sock"`` (direct unix-socket pulls), ``"driver"`` (the PR-1
    relay through the driver pipe), or ``"auto"`` (best available; the
    default).  ``shm_threshold`` is the payload size at which values leave
    the pipe.  The resolved choice of an ``auto`` run is exposed as
    ``transport_used`` after ``run``.

    ``outputs_only=True`` returns just ``{tid: value for tid in outputs}``
    and garbage-collects intermediates once their last consumer finishes —
    the memory-bounded production mode, where shm segments are unlinked
    eagerly and lineage recovery recomputes *dropped* ancestors too.
    """

    def __init__(
        self,
        n_workers: int = 2,
        *,
        policy: str = "critical_path",
        worker_speed: Optional[Sequence[float]] = None,
        pipeline_depth: int = 2,
        outputs_only: bool = False,
        fail_worker: Optional[Tuple[int, int]] = None,
        join_after: Optional[Tuple[int, int]] = None,
        progress_timeout: float = 60.0,
        start_method: str = "fork",
        seed: int = 0,
        transport: str = "auto",
        shm_threshold: int = serde.SHM_THRESHOLD,
        bandwidth: float = float(256 << 20),
    ) -> None:
        if n_workers < 1:
            raise ValueError("n_workers >= 1")
        if start_method not in ("fork", "spawn", "forkserver"):
            raise ValueError(f"unknown start_method {start_method!r}")
        if transport not in serde.TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r} "
                             f"(expected one of {serde.TRANSPORTS})")
        self.start_method = start_method
        self.n_workers = n_workers
        self.policy = policy
        self.worker_speed = list(worker_speed) if worker_speed else None
        self.pipeline_depth = max(1, pipeline_depth)
        self.outputs_only = outputs_only
        self.fail_worker = fail_worker
        self.join_after = join_after
        self.progress_timeout = progress_timeout
        self.seed = seed
        self.transport = transport
        self.transport_used: Optional[str] = None
        self.shm_threshold = max(1, shm_threshold)
        self.bandwidth = bandwidth
        self.seg_prefix: Optional[str] = None    # last run's shm name prefix
        self.stats: Dict[str, int] = {}
        self.wall_time = 0.0
        self.recovery_events: List[Dict[str, Any]] = []
        self._commands: List[Tuple] = []
        self._cmd_lock = threading.Lock()
        # stats/recovery_events/wall_time are per-run instance attributes,
        # so one executor runs ONE graph at a time; concurrent submissions
        # queue on this lock (use separate executors for parallel jobs)
        self._run_lock = threading.Lock()
        self._active = False

    # ------------------------------------------------------------- frontend
    def run(self, graph: TaskGraph,
            inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
        return self._execute(graph, inputs)

    def submit(self, graph: TaskGraph,
               inputs: Optional[Dict[str, Any]] = None,
               label: str = "") -> ClusterFuture:
        """Async submission: returns immediately with a future; the run
        executes on a background driver thread with a fresh worker pool.
        Runs on the SAME executor serialize (stats are per-run) — use one
        executor per job for true inter-job concurrency."""
        fut = ClusterFuture(label)

        def drive() -> None:
            try:
                result, stats, wall = self._execute_with_stats(graph, inputs)
                fut._set_result(result, stats=stats, wall_time=wall)
            except BaseException as e:   # noqa: BLE001 — carried by future
                fut._set_error(e)

        threading.Thread(target=drive, daemon=True,
                         name=f"cluster-driver-{label or id(fut)}").start()
        return fut

    def add_worker(self) -> None:
        """Elastic join: grow the pool (mid-run if a run is active)."""
        with self._cmd_lock:
            if self._active:
                self._commands.append(("join",))
            else:
                self.n_workers += 1

    def kill_worker(self, wid: int) -> None:
        """Chaos hook: SIGKILL worker ``wid`` of the active run."""
        with self._cmd_lock:
            self._commands.append(("kill", wid))

    # -------------------------------------------------------------- driver
    def _execute(self, graph: TaskGraph,
                 inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        return self._execute_with_stats(graph, inputs)[0]

    def _execute_with_stats(self, graph: TaskGraph,
                            inputs: Optional[Dict[str, Any]]):
        """Run + a stats/wall_time snapshot taken while the run lock is
        still held — a queued submission on the same executor reassigns
        the per-run fields the moment the lock is released."""
        graph.validate()
        with self._run_lock:
            result = self._execute_locked(graph, inputs)
            return result, dict(self.stats), self.wall_time

    def _execute_locked(self, graph: TaskGraph,
                        inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        ctx = mp.get_context(self.start_method)
        transport = self.transport_used = serde.resolve_transport(
            self.transport)
        seg_prefix = self.seg_prefix = f"rr{os.getpid():x}" \
                                       f"{uuid.uuid4().hex[:8]}"
        peer_dir = (tempfile.mkdtemp(prefix="rrpeer")
                    if transport == "sock" else None)
        driver_namer = serde.SegmentNamer(f"{seg_prefix}d")
        stats = self.stats = {
            "dispatched": 0, "steals": 0, "transfers": 0, "recomputed": 0,
            "failures": 0, "joins": 0, "dropped": 0,
            "transfers_direct": 0, "transfers_driver": 0,
            "bytes_moved": 0, "bytes_driver": 0, "bytes_direct": 0,
        }
        self.recovery_events = []
        t0 = time.perf_counter()

        store = DriverObjectStore(graph)
        workers: Dict[int, _Worker] = {}
        next_wid = 0

        def spawn() -> _Worker:
            nonlocal next_wid
            wid = next_wid
            next_wid += 1
            parent, child = ctx.Pipe(duplex=True)
            proc = ctx.Process(target=worker_main,
                               args=(wid, child, graph, inputs, transport,
                                     self.shm_threshold, seg_prefix,
                                     peer_dir),
                               daemon=True, name=f"cluster-worker-{wid}")
            proc.start()
            child.close()
            w = _Worker(wid, proc, parent)
            workers[wid] = w
            store.add_worker(wid)
            return w

        for _ in range(self.n_workers):
            spawn()

        rank = graph.critical_path_rank()
        succ = store.successors
        n_total = len(graph.nodes)
        required = (set(graph.outputs) if self.outputs_only
                    else set(graph.nodes))

        state: Dict[int, int] = {}
        for tid, node in graph.nodes.items():
            state[tid] = READY if not node.all_deps else PENDING
        done: Set[int] = set()
        finish_times: Dict[int, float] = {}
        # tid -> (wid, still-missing dep tids) for transfer-blocked dispatches
        waiting: Dict[int, Tuple[int, Set[int]]] = {}
        fetching: Dict[int, int] = {}    # dep tid -> wid the fetch went to
        error: List[BaseException] = []
        join_after = self.join_after     # consumed per run, not per executor
        last_progress = time.perf_counter()

        def alive_ids() -> List[int]:
            return [w.wid for w in workers.values() if w.alive]

        def speeds_for(wids: List[int]) -> Optional[List[float]]:
            if self.worker_speed is None:
                return None
            return [self.worker_speed[w % len(self.worker_speed)]
                    for w in wids]

        def alive_owner(tid: int) -> Optional[int]:
            return next((x for x in store.locations(tid)
                         if x in workers and workers[x].alive), None)

        # planned placement: schedule slot i -> i-th alive worker id
        plan_worker: Dict[int, int] = {}

        def make_plan(initial: bool) -> None:
            wids = alive_ids()
            if not wids:
                return
            try:
                if initial:
                    sched = list_schedule(
                        graph, len(wids), policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed)
                else:
                    # replanning mid-run knows value sizes and current
                    # placements: make the comm-cost term real so the new
                    # plan keeps consumers next to the bytes they need
                    placed = {}
                    for t in finish_times:
                        ow = alive_owner(t)
                        if ow is not None:
                            placed[t] = wids.index(ow)
                    sched = replan(
                        graph, dict(finish_times), len(wids),
                        now=time.perf_counter() - t0, policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed,
                        data_sizes=dict(store.sizes),
                        bandwidth=self.bandwidth, placed=placed)
            except Exception:            # plan is advisory; never fatal
                plan_worker.clear()
                return
            plan_worker.clear()
            for tid, p in sched.placements.items():
                plan_worker[tid] = wids[p.worker]

        make_plan(initial=True)

        # ---------------------------------------------------------- helpers
        def safe_send(w: _Worker, msg: tuple) -> bool:
            """Send to a worker; an already-dead peer (organic SIGKILL, OOM,
            segfault) becomes a failure-handled event, never an exception
            out of the driver loop."""
            try:
                w.conn.send(msg)
                return True
            except (BrokenPipeError, OSError):
                on_worker_death(w)
                return False

        def account_pipe(handle: serde.Handle) -> None:
            n = serde.pipe_nbytes(handle)
            stats["bytes_driver"] += n
            stats["bytes_moved"] += n

        def account_transfer(handle: serde.Handle) -> None:
            p, d = serde.pipe_nbytes(handle), serde.direct_nbytes(handle)
            stats["bytes_driver"] += p
            stats["bytes_direct"] += d
            stats["bytes_moved"] += p + d
            if d > 0:
                stats["transfers_direct"] += 1
            else:
                stats["transfers_driver"] += 1
            stats["transfers"] += 1

        def publish_cached(d: int) -> Optional[serde.Handle]:
            """Encode a driver-cached value for shipping; a value that
            cannot be serialized is a task error, not a worker death."""
            try:
                h = serde.encode(store.cache[d], transport=transport,
                                 threshold=self.shm_threshold,
                                 namer=driver_namer)
            except Exception as e:      # noqa: BLE001 — surfaced on future
                error.append(TaskFailed(
                    d, graph.nodes[d].name,
                    RuntimeError(f"SerializationError: result of task {d} "
                                 f"cannot be shipped to a worker: {e!r}")))
                return None
            store.set_handle(d, h)
            return h

        def build_extra(tid: int, wid: int
                        ) -> Tuple[Optional[Dict[int, Any]], Set[int]]:
            """Transfer handles for every input of ``tid`` not already
            replicated on ``wid``; the missing set needs fetches first.
            Returns (None, _) when a value failed to serialize (error set)."""
            extra: Dict[int, Any] = {}
            missing: Set[int] = set()
            for d in graph.nodes[tid].all_deps:
                if store.has_replica(d, wid):
                    continue                   # already local
                h = store.handles.get(d)
                if h is None and d in store.cache:
                    h = publish_cached(d)
                    if h is None:
                        return None, missing
                if h is not None:
                    extra[d] = h
                else:
                    missing.add(d)
            return extra, missing

        def move_cost(tid: int, wid: int) -> int:
            """Bytes that must move for ``tid`` to run on ``wid``.  A
            published value costs half (one consumer-side materialization);
            an unpublished remote value costs its full size (publish +
            materialize)."""
            cost = 0
            for d in graph.nodes[tid].all_deps:
                if store.has_replica(d, wid):
                    continue
                size = store.sizes.get(d, 0)
                if d in store.handles or d in store.cache:
                    cost += size // 2
                else:
                    cost += size
            return cost

        def try_dispatch(tid: int, w: _Worker) -> bool:
            """Assign READY task ``tid`` to worker ``w``; ship handles or
            request publication of whatever remote inputs it needs.
            Returns False when a recovery ran underneath (caller must
            re-snapshot the ready set)."""
            extra, missing = build_extra(tid, w.wid)
            if extra is None:
                return False                    # serialization task error
            if missing:
                # a "done" dep with no live owner and no durable copy is a
                # lost value the death handler didn't see (e.g. GC raced a
                # transfer): recover it through lineage like any other loss
                unreachable = {
                    d for d in missing
                    if d not in fetching and alive_owner(d) is None}
                if unreachable:
                    state[tid] = READY
                    recompute_lost(unreachable, unreachable, None)
                    return False
                state[tid] = WAITING
                waiting[tid] = (w.wid, missing)
                w.assigned.add(tid)
                for d in missing:
                    if d not in fetching:
                        ow = alive_owner(d)
                        if ow is None or \
                                not safe_send(workers[ow], ("fetch", d)):
                            # the owner died under this loop.  If the dep
                            # survives on a replica the death handler has
                            # no record of THIS waiter (fetching[d] was
                            # never set) — unwind to READY so dispatch
                            # retries against the survivors, instead of
                            # stranding the task in WAITING forever.
                            if waiting.pop(tid, None) is not None:
                                w.assigned.discard(tid)
                            if state.get(tid) == WAITING:
                                state[tid] = READY
                            return False
                        fetching[d] = ow
                return True
            return launch(tid, w, extra)

        def launch(tid: int, w: _Worker, extra: Dict[int, Any]) -> bool:
            """Ship the run message; False when the worker died under the
            send (the death handler has already reset ``tid`` to READY)."""
            state[tid] = INFLIGHT
            w.inflight.add(tid)
            if not safe_send(w, ("run", tid, extra)):
                return False
            stats["dispatched"] += 1
            for h in extra.values():
                account_transfer(h)
            return True

        def finish_waiting(tid: int) -> None:
            """All transfers for a WAITING task arrived — launch it."""
            wid, _ = waiting.pop(tid)
            w = workers[wid]
            w.assigned.discard(tid)
            if not w.alive:
                state[tid] = READY
                return
            extra, missing = build_extra(tid, wid)
            if extra is None:
                return                  # serialization task error
            if missing:                 # a handle vanished under us (GC /
                state[tid] = READY      # racing recovery): re-dispatch
                return
            launch(tid, w, extra)

        def stealable(tid: int) -> bool:
            """A task may run off-plan only when its planned home cannot
            take it now (dead, or pipeline full) — stealing exists for
            stragglers, not for letting the first worker vacuum the whole
            ready set before its peers get a dispatch turn."""
            ow = plan_worker.get(tid)
            if ow is None or ow not in workers:
                return True
            home = workers[ow]
            return not home.alive or home.load() >= self.pipeline_depth

        def dispatch() -> None:
            ready = [t for t, s in state.items() if s == READY]
            if not ready:
                return
            ready.sort(key=lambda t: (-rank[t], t))
            for w in list(workers.values()):
                if not w.alive:
                    continue
                while w.load() < self.pipeline_depth and ready:
                    # locality-aware choice: among this worker's planned
                    # tasks (or, stealing, the stealable ready window) run
                    # the one needing the fewest remote input bytes
                    window = ready[:32]
                    planned = [t for t in window
                               if plan_worker.get(t, w.wid) == w.wid]
                    pool = planned or [t for t in window if stealable(t)]
                    if not pool:
                        break       # everything here belongs to live peers
                    mine = min(pool, key=lambda t: (move_cost(t, w.wid),
                                                    -rank[t], t))
                    if not planned:
                        stats["steals"] += 1   # off-plan work
                    ready.remove(mine)
                    if state.get(mine) != READY:
                        continue    # demoted since the snapshot
                    if not try_dispatch(mine, w):
                        return      # recovery invalidated the snapshot

        def maybe_gc(tid: int) -> None:
            if not self.outputs_only or not store.collectable(tid):
                return
            for wid in list(store.locations(tid)):
                if wid in workers and workers[wid].alive:
                    safe_send(workers[wid], ("drop", [tid]))
            store.invalidate({tid})     # also unlinks its shm segments
            stats["dropped"] += 1

        def on_done(w: _Worker, tid: int, wall: float, nbytes: int,
                    replicated: Sequence[int]) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(tid)
            if state.get(tid) == DONE:
                return                              # stale duplicate
            # record transfer replicas first, so GC drops reach them too;
            # skip deps a racing recovery has invalidated (stale-but-pure
            # copies are harmless, but must not resurrect tracking state)
            for d in replicated:
                if state.get(d) == DONE:
                    store.record_replica(d, w.wid)
            state[tid] = DONE
            done.add(tid)
            finish_times[tid] = time.perf_counter() - t0
            store.record(tid, w.wid, nbytes)
            w.n_done += 1
            for d in graph.nodes[tid].all_deps:
                store.consumed(d)
                maybe_gc(d)
            for s in succ[tid]:
                if state[s] == PENDING and \
                        all(state[d] == DONE for d in graph.nodes[s].all_deps):
                    state[s] = READY
            if self.fail_worker and w.wid == self.fail_worker[0] \
                    and w.n_done >= self.fail_worker[1] and w.alive:
                kill(w)
            nonlocal join_after
            if join_after and len(done) >= join_after[0]:
                n_new, join_after = join_after[1], None
                for _ in range(n_new):
                    join_one()

        def kill(w: _Worker) -> None:
            """SIGKILL + immediate failure handling (used by injection and
            the kill_worker command; organic deaths arrive via the pipe)."""
            try:
                os.kill(w.proc.pid, signal.SIGKILL)
                w.proc.join(timeout=5.0)
            except (ProcessLookupError, OSError):
                pass
            on_worker_death(w)

        def join_one() -> None:
            w = spawn()
            stats["joins"] += 1
            make_plan(initial=False)
            return w

        def recompute_lost(needed: Set[int], lost: Set[int],
                           cause: Any) -> None:
            """Lineage recovery: schedule the minimal recompute set for
            ``needed`` lost values, then replan onto the live workers."""
            available = store.available(set(alive_ids()))
            plan = recovery_plan(graph, needed, available)
            stats["recomputed"] += len(plan)
            self.recovery_events.append({
                "worker": cause, "lost": set(lost), "needed": set(needed),
                "available": set(available), "plan": set(plan),
            })

            will_run = plan | {t for t, s in state.items() if s != DONE}
            store.invalidate(plan)
            store.reset_consumers(plan, will_run)
            for t in plan:                  # deps outside the plan get re-read
                for d in graph.nodes[t].all_deps:
                    if d not in plan:
                        store.consumers_left[d] = \
                            store.consumers_left.get(d, 0) + 1
            for t in plan:
                done.discard(t)
                finish_times.pop(t, None)
            # WAITING tasks elsewhere may block on a lost value: reset them
            for tid in list(waiting):
                wid, need = waiting[tid]
                if need & plan:
                    waiting.pop(tid)
                    workers[wid].assigned.discard(tid)
                    state[tid] = READY
            for t in plan:
                state[t] = (READY if all(state[d] == DONE
                                         for d in graph.nodes[t].all_deps)
                            else PENDING)
            # demote READY tasks whose deps just un-completed
            for tid, s in list(state.items()):
                if s == READY and any(state[d] != DONE
                                      for d in graph.nodes[tid].all_deps):
                    state[tid] = PENDING

            if not alive_ids():
                error.append(RuntimeError(
                    "cluster lost every worker; cannot recover"))
                return
            make_plan(initial=False)       # replan onto the survivors

        def on_worker_death(w: _Worker) -> None:
            nonlocal last_progress
            if not w.alive:
                return
            last_progress = time.perf_counter()
            w.alive = False
            try:
                w.conn.close()
            except OSError:
                pass
            stats["failures"] += 1

            # tasks that never completed there simply go back in the pool
            for tid in list(w.inflight):
                state[tid] = READY
            w.inflight.clear()
            for tid in list(w.assigned):
                waiting.pop(tid, None)
                state[tid] = READY
            w.assigned.clear()

            # values whose LAST copy lived in its store are lost -> lineage
            # (replicas / shm-published handles / driver cache survive)
            lost = store.drop_worker(w.wid)
            # fetches sent to the dead worker never reply: re-aim them at a
            # surviving replica, or let the recovery below reset the waiters
            for d, target in list(fetching.items()):
                if target != w.wid:
                    continue
                fetching.pop(d, None)
                if d in lost:
                    continue               # recovery resets its waiters
                ow = alive_owner(d)
                if ow is not None and safe_send(workers[ow], ("fetch", d)):
                    fetching[d] = ow
            if self.outputs_only:
                needed = {t for t in lost
                          if t in graph.outputs
                          or store.consumers_left.get(t, 0) > 0}
            else:
                needed = set(lost)
            recompute_lost(needed, lost, w.wid)

        def on_value(w: _Worker, tid: int, found: bool, handle: Any) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            fetching.pop(tid, None)
            if not found:
                # owner dropped/lost it between request and reply; try a
                # surviving replica, else recover like a partial failure
                if state.get(tid) == DONE and not store.durable(tid):
                    ow = alive_owner(tid)
                    if ow is not None:
                        if safe_send(workers[ow], ("fetch", tid)):
                            fetching[tid] = ow
                        return
                    store.invalidate({tid})
                    recompute_lost({tid}, {tid}, None)
                return
            if state.get(tid) != DONE:
                # a recovery invalidated tid while this reply was in flight:
                # the recompute supersedes it; free the stale segments
                serde.release(handle)
                return
            account_pipe(handle)
            store.set_handle(tid, handle)
            for t in list(waiting):
                entry = waiting.get(t)
                if entry is None:     # popped by a recovery mid-loop
                    continue
                _, need = entry
                need.discard(tid)
                if not need:
                    finish_waiting(t)

        def on_deplost(w: _Worker, tid: int, deps: Sequence[int]) -> None:
            """A dispatched task's input handles would not resolve (owner
            died mid-transfer / GC raced): re-queue the task and recover
            any input that is genuinely gone."""
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(tid)
            if state.get(tid) == INFLIGHT:
                state[tid] = READY
            bad = {d for d in deps
                   if state.get(d) == DONE and not store.durable(d)
                   and alive_owner(d) is None}
            if bad:
                store.invalidate(bad)
                recompute_lost(bad, bad, None)
            # inputs may themselves be mid-recompute (an earlier recovery):
            # wait for them instead of re-triggering loss detection
            if state.get(tid) == READY and any(
                    state.get(d) != DONE
                    for d in graph.nodes[tid].all_deps):
                state[tid] = PENDING

        def pump(timeout: float) -> None:
            nonlocal last_progress
            conns = {w.conn: w for w in workers.values() if w.alive}
            if not conns:
                return
            for conn in conn_wait(list(conns), timeout=timeout):
                w = conns[conn]
                try:
                    msg = conn.recv()
                except (EOFError, OSError):
                    on_worker_death(w)
                    continue
                verb = msg[0]
                if verb == "done":
                    on_done(w, msg[2], msg[3], msg[4], msg[5])
                elif verb == "value":
                    on_value(w, msg[2], msg[3], msg[4])
                elif verb == "deplost":
                    on_deplost(w, msg[2], msg[3])
                elif verb == "error":
                    if msg[3] == "MissingInput":
                        # caller-error contract: never wrapped in TaskFailed
                        error.append(MissingInput(msg[4]))
                    else:
                        node = graph.nodes.get(msg[2])
                        error.append(TaskFailed(
                            msg[2], node.name if node else f"#{msg[2]}",
                            RuntimeError(f"{msg[3]}: {msg[4]}")))
                elif verb == "bye":
                    pass

        def collect_finals() -> bool:
            """All tasks done: materialize ``required`` values into the
            driver cache — decoding published handles directly (no pipe
            traffic), fetching handles for the rest.  Returns True when
            everything required is cached."""
            nonlocal last_progress
            missing = [t for t in required if t not in store.cache]
            if not missing:
                return True
            for t in missing:
                h = store.handles.get(t)
                if h is not None:
                    try:
                        value = serde.resolve(h)
                    except serde.TransferLost:
                        store.invalidate({t})
                        recompute_lost({t}, {t}, None)
                        return False
                    store.cache_value(t, value)
                    d = serde.direct_nbytes(h)
                    if d > 0:
                        stats["bytes_direct"] += d
                        stats["bytes_moved"] += d
                        stats["transfers_direct"] += 1
                    last_progress = time.perf_counter()
                    continue
                if t in fetching:
                    continue
                ow = alive_owner(t)
                if ow is None:
                    store.invalidate({t})
                    recompute_lost({t}, {t}, None)
                    return False
                if not safe_send(workers[ow], ("fetch", t)):
                    return False        # recovery ran; resume main loop
                fetching[t] = ow
            return not [t for t in required if t not in store.cache]

        def check_commands() -> None:
            with self._cmd_lock:
                cmds, self._commands = self._commands, []
            for cmd in cmds:
                if cmd[0] == "join":
                    join_one()
                elif cmd[0] == "kill" and cmd[1] in workers \
                        and workers[cmd[1]].alive:
                    kill(workers[cmd[1]])

        def check_deaths() -> None:
            for w in list(workers.values()):
                if w.alive and not w.proc.is_alive():
                    on_worker_death(w)

        # ------------------------------------------------------- main loop
        self._active = True
        try:
            while not error:
                check_commands()
                if len(done) >= n_total:
                    if collect_finals():
                        break
                else:
                    dispatch()
                pump(timeout=0.02)
                check_deaths()
                if time.perf_counter() - last_progress > self.progress_timeout:
                    by_state: Dict[int, List[int]] = {}
                    for t, s in state.items():
                        by_state.setdefault(s, []).append(t)
                    error.append(RuntimeError(
                        f"cluster made no progress for "
                        f"{self.progress_timeout}s "
                        f"(done {len(done)}/{n_total}, states "
                        f"{ {s: sorted(ts)[:8] for s, ts in by_state.items() if s != DONE} }, "
                        f"waiting {dict(list(waiting.items())[:4])}, "
                        f"fetching {dict(list(fetching.items())[:8])}, "
                        f"inflight {[sorted(w.inflight) for w in workers.values()]})"))
        finally:
            self._active = False
            for w in workers.values():
                if w.alive:
                    try:
                        w.conn.send(("stop",))
                    except (BrokenPipeError, OSError):
                        pass
            for w in workers.values():
                w.proc.join(timeout=5.0)
                if w.proc.is_alive():
                    w.proc.terminate()
                    w.proc.join(timeout=5.0)
            # segment hygiene: free tracked handles, then sweep the run's
            # /dev/shm prefix for orphans (workers killed mid-publish)
            store.release_all()
            serde.sweep_segments(seg_prefix)
            if peer_dir is not None:
                shutil.rmtree(peer_dir, ignore_errors=True)
            self.wall_time = time.perf_counter() - t0

        if error:
            raise error[0]
        return {t: store.cache[t] for t in required}
