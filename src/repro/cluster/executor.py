"""ClusterExecutor — the multi-process / multi-host distributed runtime.

This is the paper's driver/worker architecture made real: workers are OS
processes on this host (forked or spawned, wired by duplex pipes) or on
*any* host (dialed in over TCP), a driver that schedules ready tasks onto
them, a driver-side :class:`DriverObjectStore` tracking where every result
lives, and lineage-based recovery when a worker dies.  The driver speaks
to every worker through the :class:`~repro.cluster.channel.Channel`
abstraction, so none of the scheduling/recovery logic below knows (or
cares) what wire its messages ride.

Design points (mirroring the Haskell#/Cloud-Haskell driver designs and the
mapping-decision framing of Mapple):

* **Graph compilation before dispatch.**  The purity guarantee lets the
  runtime rewrite the task graph freely, so a plan-time fusion pass
  (:mod:`repro.core.fusion`, knob ``fuse={"off","auto",N}``) clusters the
  DAG into *super-tasks* — linear chains, small same-placement fan-ins,
  and sibling groups below a cost threshold.  The whole driver state
  machine below (plan, dispatch, stealing, speculation, recovery) runs at
  super-task granularity over the plan's cluster-level graph; a
  super-task costs **one** control message, its members execute inside
  one worker frame, and only *cluster outputs* ever touch ``serde`` or
  the object store.  ``fuse="off"`` compiles the identity plan (one
  cluster per task, cluster id == task id), which is bit-for-bit the
  pre-fusion runtime — fused and unfused execution share this one code
  path.  ``stats["n_clusters"]`` / ``stats["tasks_fused"]`` report what
  the pass did.
* **Batched control plane.**  Outgoing control messages (``run`` /
  ``fetch`` / ``drop`` / ``cancel``) are coalesced into a per-worker
  outbox the driver flushes once per event-loop iteration through
  ``Channel.send_many`` — one pickle and one syscall per burst — and the
  worker's sender thread batches its replies the same way.
  ``stats["control_msgs"]`` (logical messages, both directions) vs
  ``stats["control_frames"]`` (driver-side wire writes — the flush
  count) expose the amortization on the dispatch path;
  ``stats["dispatch_overhead_s"]`` is the driver time spent choosing,
  serializing, and writing dispatches, so the fusion win is observable
  directly, not just inferable from wall clock.
* **Static plan, dynamic execution.**  ``scheduler.list_schedule`` produces
  a placement hint over the **fused** graph (critical-path priority,
  earliest-finish-time worker; its comm-cost term sees only cross-cluster
  edges); the driver follows it opportunistically and *steals* — dispatches
  a ready super-task to an idle worker that wasn't its planned home —
  whenever the plan goes stale.  Both the plan (via
  ``data_sizes``/``placed``/``worker_host`` comm costs in the scheduler)
  and the stealing choice (via a transfer-cost score over per-value sizes
  recorded at completion) are **locality-aware** at two radii: same-worker
  beats same-host beats cross-host, so a consumer lands next to its bytes
  and cross-host TCP pulls are a last resort.
* **Zero-copy data plane.**  Cross-worker values move as *handles*
  (:mod:`repro.cluster.serde`): the owner publishes the payload once into
  a ``multiprocessing.shared_memory`` segment (or serves it over its
  unix/TCP socket server — or BOTH, on a TCP data plane where same-host
  consumers then pick the shm side by host id), and the consumer
  maps/pulls it directly.  The control channel carries only messages and
  handles — ``stats["bytes_driver"]`` vs ``stats["bytes_direct"]`` make
  the split observable; ``transport="driver"`` restores the PR-1 relay
  for A/B runs.
* **Channel-based liveness.**  A forked worker's death is OS truth
  (``proc.is_alive``); a TCP worker's death is **missed heartbeats** or a
  socket EOF — and a clean shutdown says an explicit goodbye so it is
  never misread as a crash.  The driver asks each channel, not the
  process table, so SIGKILL on another machine and SIGKILL on this one
  take the same recovery path.
* **Pipelined dispatch.**  Up to ``pipeline_depth`` super-tasks are in a
  worker's channel at once, so the driver overlaps dispatch/transfer with
  execution (the futures-style async core of ``submit``/``gather``).
* **Replicas, not broadcast.**  Results stay in the producing worker's
  local store; a transfer leaves the consumer holding a replica (tracked
  per-value as a *set* of holders, each tagged with its host), so later
  consumers read locally and a value is only lost when its last holder
  dies without a durable handle.
* **Lineage fault tolerance at super-task granularity.**  On worker death
  the lost set is exactly the values with no surviving replica, no
  shm-published handle, and no driver-cached copy;
  ``lineage.recovery_plan_clusters`` gives the minimal recompute set of
  *clusters* (walking past GC'd ancestors in ``outputs_only`` runs — a
  SIGKILL mid-super-task recomputes exactly the lost cluster),
  ``scheduler.replan`` re-places the remaining work on the survivors, and
  ``stats["recomputed"]`` counts exactly ``len(plan)``.  A SIGKILL
  mid-transfer degrades the same way: consumers that already hold a stale
  handle report ``deplost`` and the super-task re-queues behind the
  recovery.
* **Speculative re-execution of stragglers.**  Purity makes duplication
  free, so with ``speculate_after=x`` an *idle* worker (no ready work
  anywhere) duplicates the most-overdue running super-task — one running
  more than ``x×`` its expected duration, where *expected* is the static
  ``list_schedule`` cost-model hint calibrated into seconds by a runtime
  EWMA of actual-vs-planned durations.  The twin placement is
  **locality-aware**: among idle workers the one nearest the task's input
  bytes (same-host copies count half of cross-host ones) runs it.  The
  first completion wins; losers get an idempotent ``cancel`` (honored
  between tasks — a loser already executing finishes and its late
  ``done`` is reconciled: recorded as a legitimate extra replica, or
  swept when the GC already dropped the value).  The *pick* is
  :func:`repro.core.simulator.pick_speculation`, shared with the
  simulator so policy and model provably agree.  ``stats`` reports
  ``n_speculative`` / ``speculative_wins`` / ``speculative_wasted_s``;
  see ``docs/speculation.md``.
* **Elasticity.**  ``add_worker()`` forks a fresh worker mid-run and
  replans onto the grown pool; on a TCP control plane, any
  ``repro-worker`` that dials the driver's address mid-run joins the same
  way.
* **Segment hygiene.**  The driver is the single unlink authority:
  handles are released when the ``consumers_left`` GC drains a value
  (``outputs_only`` runs unlink eagerly), and a run-scoped shutdown sweep
  catches ``/dev/shm`` orphans *and* stale peer-socket files from workers
  killed mid-publish.  No segment or socket file survives executor
  shutdown.

Failure injection for tests/benchmarks: ``fail_worker=(wid, n)`` SIGKILLs
worker ``wid`` after it completes ``n`` super-tasks (a remote worker is
sent a ``die`` message instead — the driver cannot signal a remote pid);
``join_after=(n, k)`` starts ``k`` extra workers once ``n`` super-tasks
have completed cluster-wide.
"""
from __future__ import annotations

import bisect
import multiprocessing as mp
import os
import pickle
import signal
import tempfile
import threading
import time
import uuid
from dataclasses import dataclass, field
from multiprocessing.connection import wait as conn_wait
from typing import Any, Dict, List, Optional, Sequence, Set, Tuple

from repro.checkpoint.runlog import (RunLog, graph_fingerprint,
                                     plan_fingerprint)
from repro.config import ClusterConfig, resolve_config
from repro.core.collectives import (CollectivesSpec, lower_collectives,
                                    parse_collectives_spec)
from repro.core.executor import MissingInput, TaskFailed
from repro.core.adaptive import (CostModel, RefuseGovernor, RunTrace,
                                 fn_key, refusion_due)
from repro.core.fusion import (DEFAULT_FANIN_COST, DEFAULT_GROUP_COST,
                               DEFAULT_KEEP_PARALLELISM, FusedPlan, FuseSpec,
                               fuse as fuse_graph, offset_plan,
                               parse_fuse_spec, refuse_frontier, splice_plan)
from repro.core.graph import TaskGraph, TaskKind
from repro.core.lineage import outage_recovery, recovery_plan_clusters
from repro.core.scheduler import fair_interleave, list_schedule, replan
from repro.core.tracing import offset_graph
from repro.core.simulator import pick_speculation

from repro.faults import FaultPlan, FaultyChannel, FaultyListener

from . import serde
from .channel import (CHANNELS, ChannelClosed, PipeChannel, SpawnChannel,
                      TcpChannel, TcpListener, _recv_frame, _send_frame,
                      host_id, is_silence, routable_ip)
from .futures import ClusterFuture
from .objectstore import DriverObjectStore
from .worker import pipe_worker_main, tcp_worker_main

PENDING, READY, WAITING, INFLIGHT, DONE = range(5)
# terminal state for clusters of a failed/cancelled resident-mode job:
# never dispatched, never resurrected by recovery, never counted done
CANCELLED = 5

WORKER_SPECS = ("local", "remote")


class DriverKilled(RuntimeError):
    """Emulated driver SIGKILL (the ``fail_driver`` chaos knob): raised
    mid-run after N cluster completions with every shutdown path skipped —
    worker sockets and the listener are torn down abruptly, no ``stop`` is
    sent, no shm sweep runs — exactly the residue a real ``kill -9`` of
    the driver process leaves.  Carries the run id so a test (or operator)
    can resume: ``ClusterExecutor(..., checkpoint_dir=d, resume=run_id)``.
    """

    def __init__(self, run_id: str) -> None:
        super().__init__(f"driver killed (emulated) during run {run_id}")
        self.run_id = run_id


class JobCancelled(RuntimeError):
    """A resident-mode job was cancelled (client disconnect, quota
    enforcement, or an explicit :meth:`ClusterExecutor.cancel_job`)
    before it completed."""


@dataclass
class _Worker:
    wid: int
    chan: Any                       # driver-side Channel
    host: str                       # machine identity (locality grouping)
    proc: Any = None                # local process handle; None for remote
    alive: bool = True
    inflight: Set[int] = field(default_factory=set)   # run sent, not done
    assigned: Set[int] = field(default_factory=set)   # waiting on transfers
    outbox: List[tuple] = field(default_factory=list)  # coalesced sends
    n_done: int = 0

    def load(self) -> int:
        return len(self.inflight) + len(self.assigned)


@dataclass
class _Job:
    """A tenant submission admitted into the resident run: an offset
    (collision-free) slice of the union graph plus everything needed to
    resolve its future back in the submitter's own id space."""
    job_id: int
    tenant: str
    base: int                       # id range [base, end) in the union
    end: int
    graph: TaskGraph                # offset lowered graph
    plan: FusedPlan                 # offset job-local plan
    required: Set[int]              # offset value tids to collect
    user_required: List[int]        # result keys, submitter id space
    coll_map: Optional[Dict[int, int]]  # user tid -> offset lowered tid
    inputs: Dict[str, Any]          # namespaced ("j<id>/<name>") inputs
    future: ClusterFuture
    cids: frozenset                 # offset cluster ids
    submitted: float = 0.0          # perf_counter at submit_job()
    first_dispatch: Optional[float] = None
    terminal: bool = False          # finished, failed, or cancelled


class ClusterExecutor:
    """Executes a :class:`TaskGraph` on a pool of worker processes.

    Satisfies the :class:`repro.core.executor.Executor` protocol — results
    are bit-identical to :func:`repro.core.executor.execute_sequential`
    because tasks are pure and the value tables are exact.

    **Graph compilation** (``fuse``): ``"off"`` (the default — one
    dispatch per task, the PR-1..4 behavior), ``"auto"`` (fuse chains /
    small fan-ins / sibling groups with the default cost model), or an
    integer ``N`` (auto rules, clusters capped at ``N`` members).  Fusion
    changes *granularity only*: results, lineage recovery, and the
    ``{tid: value}`` return contract are unchanged — fine-grained graphs
    just stop paying one driver round-trip per node.  See
    ``docs/fusion.md``.

    **Control plane** (``channel``): ``"pipe"`` (forked in-host workers,
    the default), ``"spawn"`` (fresh-interpreter in-host workers; implied
    by ``start_method="spawn"``), or ``"tcp"`` (workers dial the driver's
    listening address — the multi-host channel, with heartbeat liveness).
    With ``channel="tcp"`` the driver binds ``connect`` (default
    ``127.0.0.1:0``; the resolved address is :attr:`address`) and
    ``workers`` describes the pool: ``"local"`` entries are forked dialers
    started by the driver, ``"remote"`` entries are slots filled by
    external ``repro-worker`` processes (``python -m repro.launch.remote
    --connect <address>``) within ``accept_timeout``.  Extra dials during
    a run join elastically.

    **Data plane** (``transport``): ``"shm"`` (zero-copy shared memory),
    ``"sock"`` (direct unix-socket pulls), ``"tcp"`` (direct TCP pulls —
    the only bulk channel that crosses hosts; same-host pairs still ride
    shm via dual-published handles), ``"driver"`` (relay through the
    control channel), or ``"auto"`` (best available; ``tcp`` when the
    pool spans hosts).  ``shm_threshold`` is the payload size at which
    values leave the control channel.  The resolved choice of an ``auto``
    run is exposed as ``transport_used`` after ``run``.

    ``outputs_only=True`` returns just ``{tid: value for tid in outputs}``
    and garbage-collects intermediates once their last consumer finishes —
    the memory-bounded production mode, where shm segments are unlinked
    eagerly and lineage recovery recomputes *dropped* ancestors too.
    (Under fusion, intra-cluster intermediates never exist outside the
    worker's execution frame in the first place.)

    ``speculate_after=x`` enables speculative re-execution of stragglers:
    an idle worker duplicates a super-task running longer than ``x×`` its
    expected duration, first completion wins, the loser is cancelled
    between tasks.  Off (``None``) by default — duplication costs work, so
    it is opt-in for tail-latency-sensitive runs (``docs/speculation.md``).
    """

    def __init__(
        self,
        n_workers: Optional[int] = None,
        *,
        config: Optional[ClusterConfig] = None,
        **legacy: Any,
    ) -> None:
        # All runtime knobs live on one frozen repro.ClusterConfig; the
        # historical keyword arguments keep working for one release via
        # the shim (DeprecationWarning, once per name — repro/config.py).
        cfg = resolve_config(config, legacy)
        if n_workers is not None:
            cfg = cfg.replace(n_workers=n_workers)
        self.config = cfg
        (policy, worker_speed, pipeline_depth, outputs_only, fail_worker,
         join_after, progress_timeout, start_method, seed, transport,
         shm_threshold, bandwidth, channel, connect, workers, token,
         accept_timeout, heartbeat_interval, heartbeat_timeout,
         speculate_after, fuse, collectives, checkpoint_dir,
         checkpoint_interval, resume, rejoin_timeout, rejoin_window,
         fail_driver, fault_plan, suspect_grace, quarantine_after,
         probe_interval, heartbeat_jitter, fetch_retry) = (
            cfg.policy, cfg.worker_speed, cfg.pipeline_depth,
            cfg.outputs_only, cfg.fail_worker, cfg.join_after,
            cfg.progress_timeout, cfg.start_method, cfg.seed,
            cfg.transport,
            cfg.shm_threshold if cfg.shm_threshold is not None
            else serde.SHM_THRESHOLD,
            cfg.bandwidth, cfg.channel, cfg.connect,
            cfg.workers, cfg.token, cfg.accept_timeout,
            cfg.heartbeat_interval, cfg.heartbeat_timeout,
            cfg.speculate_after, cfg.fuse, cfg.collectives,
            cfg.checkpoint_dir, cfg.checkpoint_interval, cfg.resume,
            cfg.rejoin_timeout, cfg.rejoin_window, cfg.fail_driver,
            cfg.fault_plan, cfg.suspect_grace, cfg.quarantine_after,
            cfg.probe_interval, cfg.heartbeat_jitter, cfg.fetch_retry)
        n_workers = cfg.n_workers
        if start_method not in ("fork", "spawn", "forkserver"):
            raise ValueError(f"unknown start_method {start_method!r}")
        if resume is not None:
            if checkpoint_dir is None:
                raise ValueError("resume requires checkpoint_dir")
            from repro.checkpoint.runlog import load_run
            self._resume_state = load_run(
                os.path.join(checkpoint_dir, f"{resume}.log"))
            meta = self._resume_state.meta
            # plan identity: fusion spec / GC mode / resolved transport come
            # from the interrupted run, not from this constructor's defaults
            fuse = meta.get("fuse", fuse)
            collectives = meta.get("collectives", collectives)
            outputs_only = meta.get("outputs_only", outputs_only)
            if connect is None:
                connect = meta.get("address")
            if channel is None:
                channel = meta.get("channel")
            transport = meta.get("transport", transport)
        else:
            self._resume_state = None
        if fail_driver is not None and fail_driver < 1:
            raise ValueError("fail_driver must be a positive completion "
                             "count (or None to disable crash emulation)")
        if workers is not None:
            workers = list(workers)
            bad = [w for w in workers if w not in WORKER_SPECS]
            if bad:
                raise ValueError(f"unknown worker spec(s) {bad!r} "
                                 f"(expected one of {WORKER_SPECS})")
            n_workers = len(workers)
        if n_workers < 1:
            raise ValueError("n_workers >= 1")
        self.worker_specs = workers or ["local"] * n_workers
        self.multihost = "remote" in self.worker_specs
        if channel is None:
            if connect is not None or self.multihost:
                channel = "tcp"
            else:
                channel = "pipe" if start_method == "fork" else "spawn"
        if channel not in CHANNELS:
            raise ValueError(f"unknown channel {channel!r} "
                             f"(expected one of {CHANNELS})")
        if channel == "spawn" and start_method == "fork":
            start_method = "spawn"
        if channel == "pipe" and start_method != "fork":
            channel = "spawn"       # pipe wiring, spawn launch contract
        if self.multihost and channel != "tcp":
            raise ValueError("remote workers require channel='tcp'")
        if transport not in serde.TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r} "
                             f"(expected one of {serde.TRANSPORTS})")
        if self.multihost and transport not in serde.CROSS_HOST_TRANSPORTS:
            raise ValueError(
                f"transport {transport!r} is host-local and the worker pool "
                f"declares remote workers; pick one of "
                f"{serde.CROSS_HOST_TRANSPORTS}")
        self.start_method = start_method
        self.channel = channel
        self.n_workers = n_workers
        self.policy = policy
        self.worker_speed = list(worker_speed) if worker_speed else None
        self.pipeline_depth = max(1, pipeline_depth)
        self.outputs_only = outputs_only
        self.fail_worker = fail_worker
        self.join_after = join_after
        self.progress_timeout = progress_timeout
        self.seed = seed
        self.transport = transport
        self.transport_used: Optional[str] = None
        self.shm_threshold = max(1, shm_threshold)
        self.bandwidth = bandwidth
        self.token = token
        self.accept_timeout = accept_timeout
        self.heartbeat_interval = heartbeat_interval
        self.heartbeat_timeout = heartbeat_timeout
        if speculate_after is not None and speculate_after <= 0:
            raise ValueError("speculate_after must be a positive "
                             "×expected-duration multiple (or None to "
                             "disable speculation)")
        self.speculate_after = speculate_after
        # adaptive replanning policy (docs/adaptive.md): "off" pins every
        # planning decision to plan time; "auto" closes the measurement
        # loop (calibrated scheduling, mid-run re-fusion, derived knobs)
        self.adaptive = cfg.adaptive
        self.keep_parallelism = cfg.keep_parallelism
        self.refuse_skew = cfg.refuse_skew
        self.fuse = parse_fuse_spec(fuse)   # raises on junk, at the flag
        # collective lowering spec ("auto" | "off" | arity int): identity
        # for collective-free graphs, so the default costs nothing
        self.collectives = parse_collectives_spec(collectives)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_interval = checkpoint_interval
        self.resume = resume
        self.rejoin_timeout = rejoin_timeout
        self.rejoin_window = rejoin_window
        self.fail_driver = fail_driver
        # -- failure-handling policy (see docs/faults.md) ---------------
        # fault_plan: seeded injection plan; wraps every channel (and the
        # listener) in Faulty* decorators and ships the plan to workers so
        # their peer fetches are injectable too
        self.fault_plan = fault_plan
        # suspect_grace: seconds a silence-based (heartbeat) death verdict
        # is held as a *suspicion* before lineage recovery runs — a
        # partitioned-but-alive worker whose frames resume inside the
        # window heals with zero recomputation.  0 restores kill-on-silence.
        self.suspect_grace = max(0.0, suspect_grace)
        # flakiness scoring: a worker that goes suspect-then-heals
        # quarantine_after times is quarantined (no new dispatches, existing
        # work drains) and probed: after probe_interval of verified-healthy
        # channel it is re-admitted with its score halved
        self.quarantine_after = max(1, quarantine_after)
        self.probe_interval = max(0.0, probe_interval)
        self.heartbeat_jitter = heartbeat_jitter
        # fetch_retry: RetryPolicy workers apply to peer fetches (None =
        # serde's built-in default)
        self.fetch_retry = fetch_retry
        self.run_id: Optional[str] = None
        self.host = host_id()
        self.seg_prefix: Optional[str] = None    # last run's shm name prefix
        self.stats: Dict[str, Any] = {}
        self.wall_time = 0.0
        self.recovery_events: List[Dict[str, Any]] = []
        # one entry per twin launched: {tid, primary, twin, t} — live during
        # the run (tests/chaos hooks poll it to aim a kill at the primary)
        self.speculation_events: List[Dict[str, Any]] = []
        self._commands: List[Tuple] = []
        self._cmd_lock = threading.Lock()
        # -- resident (gateway) mode: one long-lived run admitting jobs --
        self._next_base = 0              # next free id-range base
        self._job_seq = 0
        self._resident: Optional[threading.Thread] = None
        self._resident_error: Optional[BaseException] = None
        self._shutdown = threading.Event()
        self._tenant_weights: Dict[str, float] = {}
        # stats/recovery_events/wall_time are per-run instance attributes,
        # so one executor runs ONE graph at a time; concurrent submissions
        # queue on this lock (use separate executors for parallel jobs)
        self._run_lock = threading.Lock()
        self._active = False
        # the listener outlives runs: remote workers need a stable address
        # to dial before run() is even called
        self.listener: Optional[TcpListener] = None
        self.address: Optional[str] = None
        if channel == "tcp":
            self.listener = TcpListener(connect or "127.0.0.1:0",
                                        token=token)
            if fault_plan is not None:
                self.listener = FaultyListener(self.listener, fault_plan)
            self.address = self.listener.address

    # ------------------------------------------------------------- frontend
    def run(self, graph: TaskGraph,
            inputs: Optional[Dict[str, Any]] = None) -> Dict[int, Any]:
        return self._execute(graph, inputs)

    def submit(self, graph: TaskGraph,
               inputs: Optional[Dict[str, Any]] = None,
               label: str = "") -> ClusterFuture:
        """Async submission: returns immediately with a future; the run
        executes on a background driver thread with a fresh worker pool.
        Runs on the SAME executor serialize (stats are per-run) — use one
        executor per job for true inter-job concurrency."""
        fut = ClusterFuture(label)

        def drive() -> None:
            try:
                result, stats, wall = self._execute_with_stats(graph, inputs)
                fut._set_result(result, stats=stats, wall_time=wall)
            except BaseException as e:   # noqa: BLE001 — carried by future
                fut._set_error(e)

        threading.Thread(target=drive, daemon=True,
                         name=f"cluster-driver-{label or id(fut)}").start()
        return fut

    def add_worker(self) -> None:
        """Elastic join: grow the pool (mid-run if a run is active)."""
        with self._cmd_lock:
            if self._active:
                self._commands.append(("join",))
            else:
                self.n_workers += 1
                self.worker_specs.append("local")

    def kill_worker(self, wid: int) -> None:
        """Chaos hook: SIGKILL worker ``wid`` of the active run."""
        with self._cmd_lock:
            self._commands.append(("kill", wid))

    # --------------------------------------------------- resident (gateway)
    def start_resident(self) -> None:
        """Start the long-lived resident driver: bring up the worker pool
        on a background thread and keep the run open indefinitely,
        admitting graphs submitted via :meth:`submit_job` into one shared
        union run.  Multiple tenants' jobs execute concurrently on the
        SAME pool (contrast :meth:`submit`, which serializes whole runs on
        the run lock).  The gateway service (:mod:`repro.gateway`) is the
        intended caller; stop with :meth:`shutdown_resident`."""
        if self._resident is not None and self._resident.is_alive():
            return
        self._shutdown.clear()
        self._resident_error = None

        def drive() -> None:
            try:
                with self._run_lock:
                    self._execute_locked(TaskGraph(), {}, resident=True)
            except BaseException as e:  # noqa: BLE001 — surfaced on jobs
                self._resident_error = e
            # jobs queued after the loop died would hang forever: fail
            # them with the cause (admitted jobs were failed in the run)
            exc = self._resident_error or RuntimeError(
                "resident executor shut down")
            with self._cmd_lock:
                cmds, self._commands = self._commands, []
            for cmd in cmds:
                if cmd[0] == "job":
                    cmd[1].future._set_error(exc)

        self._resident = threading.Thread(
            target=drive, daemon=True, name="cluster-resident-driver")
        self._resident.start()

    def submit_job(self, graph: TaskGraph,
                   inputs: Optional[Dict[str, Any]] = None, *,
                   tenant: str = "default",
                   outputs_only: Optional[bool] = None,
                   label: str = "",
                   admission=None) -> ClusterFuture:
        """Admit ``graph`` into the resident run and return its future.

        ``admission`` is an optional gate called with the job's cluster
        count after fusion but before any id space is consumed or the job
        is queued; raising from it (the gateway raises
        :class:`repro.gateway.QuotaExceeded`) aborts the submission with
        no residue.  The graph is lowered and fused in its own pristine
        id space (the
        deterministic passes every backend shares, so results stay
        bit-identical to ``execute_sequential``), then transplanted into
        a private ``[base, base+n)`` range of the union run — task ids,
        cluster ids, object-store keys, lineage and run-log records are
        all namespaced per job, and placeholder inputs become
        ``"j<id>/<name>"`` so two tenants' ``"x"`` never collide.  The
        future's result dict is keyed by the SUBMITTED graph's own ids.
        """
        if self._resident is None or not self._resident.is_alive():
            if self._resident_error is not None:
                raise RuntimeError("resident executor died") \
                    from self._resident_error
            raise RuntimeError(
                "submit_job requires a resident executor "
                "(call start_resident() first)")
        graph.validate()
        oo = self.outputs_only if outputs_only is None else outputs_only
        user_graph = graph
        lowered, coll_map = lower_collectives(graph, self.collectives)
        jplan = fuse_graph(lowered, self.fuse)
        user_required = (sorted(user_graph.outputs) if oo
                         else sorted(user_graph.nodes))
        if admission is not None:
            admission(len(jplan.cgraph.nodes))
        width = (max(lowered.nodes) + 1) if lowered.nodes else 0
        with self._cmd_lock:
            base = self._next_base
            self._next_base += width
            job_id = self._job_seq
            self._job_seq += 1
        ns = f"j{job_id}/"
        off_graph = offset_graph(lowered, base, input_ns=ns)
        off_plan = offset_plan(jplan, base, off_graph)
        if coll_map is None:
            cmap = None
            req = {t + base for t in user_required}
        else:
            cmap = {t: coll_map[t] + base for t in user_graph.nodes}
            req = {cmap[t] for t in user_required}
        fut = ClusterFuture(label or f"{tenant}/j{job_id}")
        # admission-control hints for the gateway: cluster count and job
        # id are known the moment the job is fused, long before the
        # resident loop admits it (cancel_job takes the job id)
        fut.n_clusters = len(off_plan.cgraph.nodes)
        fut.job_id = job_id
        job = _Job(job_id=job_id, tenant=tenant, base=base,
                   end=base + width, graph=off_graph, plan=off_plan,
                   required=req, user_required=list(user_required),
                   coll_map=cmap,
                   inputs={ns + k: v for k, v in (inputs or {}).items()},
                   future=fut, cids=frozenset(off_plan.cgraph.nodes),
                   submitted=time.perf_counter())
        with self._cmd_lock:
            self._commands.append(("job", job))
        return fut

    def cancel_job(self, job_id: int, reason: str = "cancelled") -> None:
        """Cancel an admitted job (client disconnect, quota enforcement):
        its future fails with :class:`JobCancelled`, its unfinished
        clusters are withdrawn and its values collected — other tenants'
        jobs are untouched."""
        with self._cmd_lock:
            self._commands.append(
                ("canceljob", job_id, JobCancelled(reason)))

    def log_record(self, *record) -> None:
        """Journal an out-of-band record into the resident run's log (a
        no-op when checkpointing is off).  The gateway uses this for its
        ``session``/``sessionend`` records so a resumed gateway can
        re-create tenant sessions; the append happens on the driver
        thread, keeping the run log single-writer."""
        with self._cmd_lock:
            self._commands.append(("logrec", record))

    def set_tenant_weight(self, tenant: str, weight: float) -> None:
        """Fair-share weight for ``tenant`` in the resident dispatch tier
        (default 1.0; higher means more dispatch slots under contention,
        fractions accumulate as deficits)."""
        self._tenant_weights[tenant] = float(weight)

    def shutdown_resident(self, timeout: float = 30.0) -> None:
        """Stop the resident driver and tear down the pool.  Jobs still
        in flight fail with ``"resident executor shut down"`` — the
        gateway drains its sessions before calling this.  Re-raises the
        resident loop's error, if it died of one."""
        if self._resident is None:
            return
        self._shutdown.set()
        self._resident.join(timeout=timeout)
        self._resident = None
        if self._resident_error is not None:
            err, self._resident_error = self._resident_error, None
            raise err

    def close(self) -> None:
        """Release the executor's listening socket (TCP channel only)."""
        if self.listener is not None:
            self.listener.close()
            self.listener = None

    def __del__(self) -> None:      # pragma: no cover — GC timing
        try:
            self.close()
        except Exception:
            pass

    # -------------------------------------------------------------- driver
    def _execute(self, graph: TaskGraph,
                 inputs: Optional[Dict[str, Any]]) -> Dict[int, Any]:
        return self._execute_with_stats(graph, inputs)[0]

    def _execute_with_stats(self, graph: TaskGraph,
                            inputs: Optional[Dict[str, Any]]):
        """Run + a stats/wall_time snapshot taken while the run lock is
        still held — a queued submission on the same executor reassigns
        the per-run fields the moment the lock is released."""
        graph.validate()
        with self._run_lock:
            result = self._execute_locked(graph, inputs)
            return result, dict(self.stats), self.wall_time

    def _execute_locked(self, graph: TaskGraph,
                        inputs: Optional[Dict[str, Any]],
                        resident: bool = False) -> Dict[int, Any]:
        if resident:
            # the union run admits jobs mid-flight: its graph/inputs are
            # live mutable objects, growing at admission, shrinking at
            # retirement
            inputs = dict(inputs) if inputs else {}
        ctx = mp.get_context(self.start_method)
        transport = self.transport_used = serde.resolve_transport(
            self.transport, multihost=self.multihost)
        seg_prefix = self.seg_prefix = f"rr{os.getpid():x}" \
                                       f"{uuid.uuid4().hex[:8]}"
        peer_dir = (tempfile.mkdtemp(prefix="rrpeer")
                    if transport == "sock" else None)
        driver_namer = serde.SegmentNamer(f"{seg_prefix}d")

        # -- collective lowering: COLLECTIVE nodes become staged tree hops
        # BEFORE fusion/scheduling, so the whole driver below (and every
        # worker, which receives this graph) runs over the lowered DAG.
        # coll_map is None for the identity (no collectives / spec off) —
        # the common case, which stays byte-identical to the old runtime.
        # The run's external contract stays in USER tids: ``required`` is
        # mapped through coll_map and mapped back in the return dict.
        user_graph = graph
        graph, coll_map = lower_collectives(graph, self.collectives)
        user_required = (set(user_graph.outputs) if self.outputs_only
                         else set(user_graph.nodes))

        # -- graph compilation: the driver below runs over the CLUSTER graph
        # (fuse="off" -> identity plan, cg is graph, cluster id == task id).
        # A resident run starts from an explicitly EMPTY non-identity plan:
        # jobs are fused in their own id space at submit time and spliced
        # in at admission — the union must never be the identity plan, or
        # the first fused job would collide the cid and tid namespaces.
        # keep_parallelism for the INITIAL fuse: explicit config wins;
        # adaptive mode derives it from the pool size (never below the
        # static default, so small pools reproduce historical plans); a
        # resumed run replays the interrupted run's pinned value so the
        # plan fingerprint below can match even if the pool changed.
        if self._resume_state is not None:
            kp = self._resume_state.meta.get(
                "keep_par", DEFAULT_KEEP_PARALLELISM)
        elif self.keep_parallelism is not None:
            kp = self.keep_parallelism
        elif self.adaptive != "off":
            kp = max(DEFAULT_KEEP_PARALLELISM, 2 * self.n_workers)
        else:
            kp = DEFAULT_KEEP_PARALLELISM
        if resident:
            plan = FusedPlan(graph=graph, cgraph=TaskGraph(), members={},
                             cluster_of={}, outputs={}, ext_deps={},
                             consumers={}, spec=self.fuse)
        else:
            plan = fuse_graph(graph, self.fuse, keep_parallelism=kp)
        cg = plan.cgraph
        required = (user_required if coll_map is None
                    else {coll_map[t] for t in user_required})
        fusion_view = plan.worker_view(required)

        stats = self.stats = {
            "dispatched": 0, "steals": 0, "transfers": 0, "recomputed": 0,
            "failures": 0, "joins": 0, "dropped": 0,
            "transfers_direct": 0, "transfers_driver": 0,
            "bytes_moved": 0, "bytes_driver": 0, "bytes_direct": 0,
            "n_speculative": 0, "speculative_wins": 0,
            "speculative_swept": 0, "speculative_wasted_s": 0.0,
            "n_clusters": len(cg.nodes), "tasks_fused": plan.n_fused,
            # collective-lowering observability: how many user collective
            # roots the run had, and how many staged hop nodes they became
            "collective_roots": sum(
                1 for n in user_graph.nodes.values()
                if n.kind is TaskKind.COLLECTIVE and "collective" in n.meta),
            "collective_stages": (0 if coll_map is None
                                  else len(graph.nodes)
                                  - len(user_graph.nodes)),
            "control_msgs": 0, "control_frames": 0,
            "dispatch_overhead_s": 0.0, "resumed_clusters": 0,
            # failure-policy observability: suspicion episodes and their
            # outcomes (healed vs escalated to death), driver-relay
            # degradations that saved a recompute, and the quarantine
            # round-trip counters
            "suspected": 0, "healed": 0, "relay_fallbacks": 0,
            "quarantined": 0, "readmitted": 0, "deplosts": 0,
            # adaptive-replanning observability (docs/adaptive.md): the
            # calibrated cost unit (seconds per abstract cost unit), the
            # measured per-dispatch overhead, how many mid-run re-fusions
            # fired (and how many a resume replayed from the journal),
            # calibrated replans triggered, the governor's last observed
            # skew, and the variance-derived speculation threshold
            "cost_unit_s": 0.0, "dispatch_cost_s": 0.0,
            "refusions": 0, "refusions_replayed": 0, "replan_triggers": 0,
            "adaptive_skew": 0.0, "adaptive_speculate_after": 0.0,
        }
        if resident:
            stats.update({"jobs_admitted": 0, "jobs_completed": 0,
                          "jobs_failed": 0})
        self.recovery_events = []
        self.speculation_events = []
        t0 = time.perf_counter()

        # -- durable control-plane state: one append-only run log per run.
        # A fresh run writes a `begin` record pinning everything plan
        # identity depends on; a resumed run validates those fingerprints
        # (same graph + same fusion => same cluster ids, so the logged
        # frontier is meaningful) and appends a `resume` marker carrying
        # the new shm prefix.
        rs = self._resume_state
        self._resume_state = None
        run_id = self.run_id = self.resume or uuid.uuid4().hex[:12]
        self.resume = None
        graph_fp = graph_fingerprint(graph)
        plan_fp = plan_fingerprint(plan)
        old_prefixes: List[str] = []
        if rs is not None:
            if rs.meta.get("graph_fp") != graph_fp:
                raise ValueError(
                    f"resume {run_id}: graph does not match the "
                    "interrupted run (task ids / deps / kinds differ)")
            if rs.meta.get("plan_fp") != plan_fp:
                raise ValueError(
                    f"resume {run_id}: fusion plan does not match the "
                    "interrupted run (cluster identity differs)")
            old_prefixes = [p for p in rs.seg_prefixes if p != seg_prefix]
            # replay journaled adaptive re-fusions IN ORDER before any
            # resume bookkeeping: the interrupted run's `done` claims for
            # post-refusion cids only make sense against the post-splice
            # plan, and the object store built below must count consumers
            # against that plan too.  plan_fp above pinned the PRE-splice
            # plan, so fingerprints were compared apples-to-apples.
            for retired, clusters in rs.refusions:
                splice_plan(plan, retired, [tuple(c) for c in clusters])
            if rs.refusions:
                fusion_view = plan.worker_view(required)
                stats["n_clusters"] = len(cg.nodes)
                stats["tasks_fused"] = plan.n_fused
                stats["refusions_replayed"] = len(rs.refusions)
        runlog: Optional[RunLog] = None
        if self.checkpoint_dir is not None:
            os.makedirs(self.checkpoint_dir, exist_ok=True)
            runlog = RunLog(
                os.path.join(self.checkpoint_dir, f"{run_id}.log"),
                interval=self.checkpoint_interval)
            if rs is None:
                runlog.append("begin", {
                    "run_id": run_id, "graph_fp": graph_fp,
                    "plan_fp": plan_fp, "fuse": self.fuse,
                    "collectives": self.collectives,
                    "outputs_only": self.outputs_only,
                    "address": self.address, "channel": self.channel,
                    "transport": transport, "seg_prefix": seg_prefix,
                    "n_clusters": len(cg.nodes), "resident": resident,
                    "keep_par": kp, "adaptive": self.adaptive,
                })
            else:
                runlog.append("resume", {"seg_prefix": seg_prefix})
            runlog.flush()
            # resume lease: tells a repro-worker's startup sweep that this
            # run's shm segments are (or may soon be) owned by a live or
            # resumable driver — even when the recorded driver pid is dead
            # (a SIGKILL'd driver inside its rejoin window).  The lease is
            # refreshed from the main loop and cleared on clean shutdown;
            # old incarnations' prefixes are re-leased because their
            # surviving segments are this run's recovery inputs.
            lease_window = (self.rejoin_window
                            if self.rejoin_window is not None
                            else max(60.0, self.progress_timeout))
            for p in [seg_prefix] + old_prefixes:
                serde.write_resume_lease(p, run_id, lease_window)
            last_lease = time.monotonic()

        store = DriverObjectStore(graph, plan=plan)
        workers: Dict[int, _Worker] = {}
        # resumed runs keep the interrupted run's worker-id space: rejoiners
        # reclaim their old wid, fresh spawns start above every recorded one
        next_wid = (max(rs.workers) + 1 if rs is not None and rs.workers
                    else 0)
        listener = self.listener
        # graph shipped once per run to graph-less (remote) dialers
        graph_blob: List[Optional[bytes]] = [None]
        # handshaken dials not yet matched to the local proc that owns them
        dial_stash: List[Tuple[Any, dict]] = []

        def run_config(hello: dict) -> dict:
            # the address OTHER workers use to reach this worker's peer
            # data-plane server.  A local worker dials the driver over
            # loopback, so the IP the driver saw (127.x) is unroutable
            # from remote consumers — advertise this machine's real
            # interface instead when the pool spans hosts.
            # any TCP-listener run can gain cross-host joiners mid-run
            # (not just declared-remote pools), so the rewrite keys on
            # the data plane being TCP, not on self.multihost
            peer_ip = hello.get("peer_ip", "127.0.0.1")
            if listener is not None and transport == "tcp" \
                    and peer_ip.startswith("127."):
                peer_ip = routable_ip()
            return {
                "transport": transport,
                "shm_threshold": self.shm_threshold,
                "seg_prefix": seg_prefix,
                "peer_dir": peer_dir,
                "peer_host": peer_ip,
                "fusion": fusion_view,
                "heartbeat_interval": self.heartbeat_interval,
                # the worker tolerates a longer driver silence than the
                # driver tolerates of it: the driver's loop always has
                # traffic to send, a worker mid-task may not
                "worker_heartbeat_timeout": max(self.heartbeat_timeout * 3,
                                                self.progress_timeout),
                # checkpointed runs arm the worker-side rejoin loop: a
                # dropped driver socket means "re-dial with this run id for
                # up to rejoin_window seconds", not "exit".  Uncheckpointed
                # runs keep the die-on-silence contract — there is nothing
                # to resume into.
                "run_id": run_id if runlog is not None else None,
                "rejoin_window": (self.rejoin_window
                                  if self.rejoin_window is not None
                                  else max(60.0, self.progress_timeout)),
                "heartbeat_jitter": self.heartbeat_jitter,
                # data-plane fault injection + retry policy travel in the
                # welcome so every worker (forked, spawned, remote) applies
                # the same seeded plan to its peer fetches
                "fault_plan": self.fault_plan,
                "fetch_retry": self.fetch_retry,
            }

        def wrap_chan(chan: Any, wid: int) -> Any:
            """Decorate a driver-side channel with the run's fault plan
            (identity when no plan is armed).  The handshake itself stays
            raw — injection begins once the worker is adopted."""
            if self.fault_plan is None:
                return chan
            return FaultyChannel(chan, self.fault_plan, wid,
                                 silence_timeout=self.heartbeat_timeout)

        def ship_graph() -> bytes:
            if graph_blob[0] is None:
                try:
                    graph_blob[0] = pickle.dumps((graph, inputs), protocol=5)
                except Exception as e:
                    raise ValueError(
                        "graph is not picklable, so it cannot be shipped to "
                        "a remote worker that did not inherit it (use "
                        "module-level task functions, as with "
                        f"start_method='spawn'): {e!r}") from e
            return graph_blob[0]

        def adopt(sock, hello: dict, proc=None) -> _Worker:
            """Driver half of the TCP handshake: assign a wid, send the
            welcome (config + graph for graph-less workers), wrap the
            socket in a heartbeat-tracked channel."""
            nonlocal next_wid
            worker_host = hello.get("host", "?")
            if worker_host != self.host \
                    and transport not in serde.CROSS_HOST_TRANSPORTS:
                # a cross-host dial into a host-local data plane can never
                # resolve handles; refuse it with a reason, loudly
                msg = (f"worker on host {worker_host!r} cannot join a "
                       f"transport={transport!r} run (host-local data "
                       f"plane); use transport='tcp' or 'driver'")
                try:
                    from .channel import _send_frame
                    _send_frame(sock, pickle.dumps(("reject", msg),
                                                   protocol=5))
                except OSError:
                    pass
                try:
                    sock.close()
                except OSError:
                    pass
                raise ValueError(msg)
            try:
                blob = None if hello.get("has_graph") else ship_graph()
            except ValueError as e:
                try:
                    from .channel import _send_frame
                    _send_frame(sock, pickle.dumps(("reject", str(e)),
                                                   protocol=5))
                except OSError:
                    pass
                try:
                    sock.close()
                except OSError:
                    pass
                raise
            chan = TcpChannel(sock,
                              heartbeat_interval=self.heartbeat_interval,
                              heartbeat_timeout=self.heartbeat_timeout,
                              heartbeat_jitter=self.heartbeat_jitter,
                              proc=proc)
            wid = next_wid
            next_wid += 1
            try:
                chan.send(("welcome", wid, run_config(hello), blob))
            except ChannelClosed as e:
                chan.close()
                raise TimeoutError(f"worker dial died during welcome: "
                                   f"{e}") from e
            w = _Worker(wid, wrap_chan(chan, wid), worker_host, proc=proc)
            workers[wid] = w
            store.add_worker(wid, host=worker_host)
            if runlog is not None:
                runlog.append("worker", wid, worker_host)
            return w

        def heartbeat_all() -> None:
            """Keep already-adopted workers' driver-silence watchdogs fed
            while the driver is parked in an adoption barrier (the main
            loop isn't running yet, so nobody else sends)."""
            for w in workers.values():
                if w.alive:
                    w.chan.maybe_heartbeat()

        def adopt_dialer_for(proc) -> _Worker:
            """Match a handshaken dial to the local process we just
            started (by pid), stashing unrelated dials (remote workers
            arriving early) for later adoption."""
            assert listener is not None
            for i, (sock, hello) in enumerate(dial_stash):
                if hello.get("pid") == proc.pid:
                    dial_stash.pop(i)
                    return adopt(sock, hello, proc=proc)
            deadline = time.monotonic() + self.accept_timeout
            while True:
                if not proc.is_alive():
                    # a dialer that died at bootstrap (import error, OOM)
                    # will never dial: fail now with the real cause, not
                    # after a silent accept_timeout hang
                    raise RuntimeError(
                        f"local worker (pid {proc.pid}) exited with code "
                        f"{proc.exitcode} before dialing {self.address}")
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"local worker pid {proc.pid} never dialed "
                        f"{self.address} within {self.accept_timeout}s")
                heartbeat_all()
                try:
                    sock, hello = listener.get_worker(min(0.5, remaining))
                except TimeoutError:
                    continue        # re-check the dialer's pulse
                if hello.get("pid") == proc.pid:
                    return adopt(sock, hello, proc=proc)
                dial_stash.append((sock, hello))

        def spawn() -> _Worker:
            """Start one local worker on the configured channel family."""
            nonlocal next_wid
            if self.channel == "tcp":
                # fork children must drop every inherited driver-side fd:
                # the listener (else a SIGKILL'd driver's port stays bound
                # by its own workers and the resumed driver can never
                # re-bind it) AND the accepted sockets of already-adopted
                # peers (a child holding a dup keeps that connection alive
                # past the driver's death, so the peer never sees EOF and
                # never starts its rejoin dial)
                inherited = ([listener.fileno()]
                             if listener is not None else [])
                for ow in workers.values():
                    s = getattr(ow.chan, "sock", None)
                    if ow.alive and s is not None:
                        try:
                            inherited.append(s.fileno())
                        except OSError:
                            pass
                proc = ctx.Process(
                    target=tcp_worker_main, args=(self.address,),
                    kwargs=({"token": self.token, "graph": graph,
                             "inputs": inputs,
                             "close_fds": tuple(inherited)}
                            if self.start_method == "fork"
                            else {"token": self.token}),
                    daemon=True, name="cluster-worker-dialer")
                proc.start()
                return adopt_dialer_for(proc)
            wid = next_wid
            next_wid += 1
            parent, child = ctx.Pipe(duplex=True)
            proc = ctx.Process(target=pipe_worker_main,
                               args=(wid, child, graph, inputs, transport,
                                     self.shm_threshold, seg_prefix,
                                     peer_dir, fusion_view,
                                     self.fault_plan, self.fetch_retry),
                               daemon=True, name=f"cluster-worker-{wid}")
            proc.start()
            child.close()
            cls = PipeChannel if self.channel == "pipe" else SpawnChannel
            w = _Worker(wid, wrap_chan(cls(parent, proc), wid),
                        self.host, proc=proc)
            workers[wid] = w
            store.add_worker(wid, host=self.host)
            if runlog is not None:
                runlog.append("worker", wid, self.host)
            return w

        def adopt_remote() -> _Worker:
            """Fill one declared ``remote`` slot from the dial queue."""
            assert listener is not None
            if dial_stash:
                sock, hello = dial_stash.pop(0)
                return adopt(sock, hello, proc=None)
            deadline = time.monotonic() + self.accept_timeout
            while True:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    raise TimeoutError(
                        f"no worker dialed {self.address} within "
                        f"{self.accept_timeout}s (start workers with: "
                        f"python -m repro.launch.remote --connect "
                        f"{self.address})")
                heartbeat_all()     # earlier adoptees must not starve
                try:
                    sock, hello = listener.get_worker(min(0.5, remaining))
                except TimeoutError:
                    continue
                return adopt(sock, hello, proc=None)

        rank = cg.critical_path_rank()
        csucc = cg.successors()
        n_total = len(cg.nodes)

        state: Dict[int, int] = {}
        for cid, node in cg.nodes.items():
            state[cid] = READY if not node.all_deps else PENDING
        done: Set[int] = set()
        finish_times: Dict[int, float] = {}
        # cid -> (wid, still-missing input value tids) for transfer-blocked
        waiting: Dict[int, Tuple[int, Set[int]]] = {}
        fetching: Dict[int, int] = {}    # value tid -> wid the fetch went to
        # -- partition-aware liveness (docs/faults.md): a silence verdict
        # is a SUSPICION first, a death only after suspect_grace ---------
        suspects: Dict[int, float] = {}     # wid -> first-suspected time
        flake_score: Dict[int, float] = {}  # wid -> suspect-then-heal count
        quarantined: Dict[int, float] = {}  # wid -> healthy-since (probe t0)
        # value tid -> inline handle: the driver-relay degradation for
        # deps whose direct transfer exhausted its retries with the owner
        # still alive (relayed, never recomputed)
        relay_handles: Dict[int, serde.Handle] = {}
        # -- speculation state: a super-task may run on SEVERAL workers --
        runners: Dict[int, Set[int]] = {}         # cid -> wids running it now
        run_started: Dict[int, Dict[int, float]] = {}  # cid -> wid -> t_start
        spec_twins: Dict[int, Set[int]] = {}      # cid -> speculative wids
        # expected durations: static plan hint (cost units), calibrated to
        # seconds by the cost model's EWMA of actual/planned — the same
        # 0.9/0.1 blend the launchers' straggler detector uses.  The model
        # is always fed (its unit_s subsumes the old bare ewma_ratio);
        # whether its output DRIVES decisions is gated on self.adaptive.
        planned_dur: Dict[int, float] = {
            c: max(n.cost, 1e-6) for c, n in cg.nodes.items()}
        cost_model = CostModel()
        governor = RefuseGovernor(skew_threshold=self.refuse_skew)
        # replayed re-fusions count against the per-run cap: a resumed
        # driver continues the interrupted run's budget, not a fresh one
        governor.fired = stats["refusions_replayed"]
        trace = RunTrace(n_workers=self.n_workers)
        self.last_trace = trace
        error: List[BaseException] = []
        join_after = self.join_after     # consumed per run, not per executor
        last_progress = time.perf_counter()

        # -- resident-mode job state: admitted jobs by id, plus a sorted
        # span index mapping ANY task/cluster id to its owning job (ids of
        # a job live in [base, end), cluster ids included; empty and inert
        # for ordinary single-graph runs) -------------------------------
        jobs: Dict[int, _Job] = {}
        job_spans: List[Tuple[int, int, _Job]] = []
        span_starts: List[int] = []

        def job_of(x: int) -> Optional[_Job]:
            i = bisect.bisect_right(span_starts, x) - 1
            if i >= 0:
                b, e, j = job_spans[i]
                if b <= x < e:
                    return j
            return None

        def alive_ids() -> List[int]:
            return [w.wid for w in workers.values() if w.alive]

        def speeds_for(wids: List[int]) -> Optional[List[float]]:
            if self.worker_speed is None:
                return None
            return [self.worker_speed[w % len(self.worker_speed)]
                    for w in wids]

        def hosts_for(wids: List[int]) -> List[str]:
            return [workers[w].host for w in wids]

        def alive_owner(tid: int) -> Optional[int]:
            return next((x for x in store.locations(tid)
                         if x in workers and workers[x].alive), None)

        def cluster_sizes() -> Dict[int, int]:
            """Per-cluster output bytes for the replan comm-cost term —
            only values that actually cross cluster edges count."""
            out: Dict[int, int] = {}
            for cid, outs in plan.outputs.items():
                s = sum(store.sizes.get(v, 0) for v in outs)
                if s:
                    out[cid] = s
            return out

        # planned placement: schedule slot i -> i-th alive worker id
        plan_worker: Dict[int, int] = {}

        def make_plan(initial: bool) -> None:
            wids = alive_ids()
            if not wids:
                return
            # calibrated scheduling (docs/adaptive.md): once the cost
            # model has a measured seconds-per-unit rate, scale abstract
            # costs into seconds so the scheduler's size/bandwidth comm
            # term competes on the same axis.  planned_dur stays in UNITS
            # (divided back below) — the speculation overdue test
            # multiplies by unit_s itself.
            scale = (cost_model.unit_s
                     if self.adaptive != "off" and cost_model.unit_s
                     else 1.0)
            try:
                if initial:
                    sched = list_schedule(
                        cg, len(wids), policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed,
                        worker_host=hosts_for(wids), cost_scale=scale)
                else:
                    # replanning mid-run knows value sizes and current
                    # placements: make the comm-cost term real so the new
                    # plan keeps consumers next to the bytes they need —
                    # and, via worker_host, on the right machine
                    placed = {}
                    for c in finish_times:
                        for v in plan.outputs[c]:
                            ow = alive_owner(v)
                            if ow is not None:
                                placed[c] = wids.index(ow)
                                break
                    sched = replan(
                        cg, dict(finish_times), len(wids),
                        now=time.perf_counter() - t0, policy=self.policy,
                        worker_speed=speeds_for(wids), seed=self.seed,
                        data_sizes=cluster_sizes(),
                        bandwidth=self.bandwidth, placed=placed,
                        worker_host=hosts_for(wids), cost_scale=scale)
            except Exception:            # plan is advisory; never fatal
                plan_worker.clear()
                return
            plan_worker.clear()
            for cid, p in sched.placements.items():
                plan_worker[cid] = wids[p.worker]
            # cost-model hint for the speculation overdue test, kept in
            # cost units (node.cost is the pre-plan fallback)
            for cid, dur in sched.expected_durations().items():
                planned_dur[cid] = max(dur / scale, 1e-6)
            if not initial:
                stats["replan_triggers"] += 1

        # ---------------------------------------------------------- helpers
        def post(w: _Worker, msg: tuple) -> None:
            """Buffer a control message in the worker's outbox; the pump
            loop flushes every outbox once per iteration through
            ``Channel.send_many`` — one pickle + one syscall per burst.
            A peer that died under the buffer surfaces at flush as a
            failure-handled event, exactly like a failed direct send."""
            w.outbox.append(msg)

        def flush(w: _Worker) -> bool:
            if not w.outbox:
                return True
            msgs, w.outbox = w.outbox, []
            t = time.perf_counter()
            try:
                w.chan.send_many(msgs)
            except ChannelClosed:
                stats["dispatch_overhead_s"] += time.perf_counter() - t
                on_worker_death(w)
                return False
            stats["control_msgs"] += len(msgs)
            stats["control_frames"] += 1
            stats["dispatch_overhead_s"] += time.perf_counter() - t
            return True

        def flush_all() -> None:
            for w in list(workers.values()):
                if w.alive and w.outbox:
                    flush(w)

        def safe_send(w: _Worker, msg: tuple) -> bool:
            """Immediate (unbatched) send for out-of-band messages
            (``die``/``stop``); an already-dead peer becomes a
            failure-handled event, never an exception out of the driver
            loop."""
            try:
                w.chan.send(msg)
                return True
            except ChannelClosed:
                on_worker_death(w)
                return False

        def account_pipe(handle: serde.Handle) -> None:
            n = serde.pipe_nbytes(handle)
            stats["bytes_driver"] += n
            stats["bytes_moved"] += n

        def account_transfer(handle: serde.Handle) -> None:
            p, d = serde.pipe_nbytes(handle), serde.direct_nbytes(handle)
            stats["bytes_driver"] += p
            stats["bytes_direct"] += d
            stats["bytes_moved"] += p + d
            if d > 0:
                stats["transfers_direct"] += 1
            else:
                stats["transfers_driver"] += 1
            stats["transfers"] += 1

        def task_error(tid: int, exc: BaseException) -> None:
            """Route a task-level failure: in a resident run a failure
            belonging to some tenant's job fails ONLY that job's future
            (isolation); everything else — and every single-graph run —
            keeps the fail-the-run contract.  ``error`` stays reserved
            for infrastructure-fatal conditions."""
            j = job_of(tid)
            if j is not None:
                fail_job(j, exc)
            else:
                error.append(exc)

        def publish_cached(d: int) -> Optional[serde.Handle]:
            """Encode a driver-cached value for shipping; a value that
            cannot be serialized is a task error, not a worker death."""
            try:
                h = serde.encode(store.cache[d], transport=transport,
                                 threshold=self.shm_threshold,
                                 namer=driver_namer)
            except Exception as e:      # noqa: BLE001 — surfaced on future
                node = graph.nodes.get(d)
                task_error(d, TaskFailed(
                    d, node.name if node else f"#{d}",
                    RuntimeError(f"SerializationError: result of task {d} "
                                 f"cannot be shipped to a worker: {e!r}")))
                return None
            store.set_handle(d, h)
            if runlog is not None and serde.is_durable(h):
                runlog.append("hnd", d, pickle.dumps(h, protocol=5))
            return h

        def build_extra(cid: int, wid: int
                        ) -> Tuple[Optional[Dict[int, Any]], Set[int]]:
            """Transfer handles for every external input of super-task
            ``cid`` not already replicated on ``wid``; the missing set
            needs fetches first.  Returns (None, _) when a value failed to
            serialize (error set)."""
            extra: Dict[int, Any] = {}
            missing: Set[int] = set()
            for d in plan.ext_deps[cid]:
                if store.has_replica(d, wid):
                    continue                   # already local
                # a relayed value ships inline (driver transport): its
                # direct handle already failed a consumer's full retry run
                h = relay_handles.get(d) or store.handles.get(d)
                if h is None and d in store.cache:
                    h = publish_cached(d)
                    if h is None:
                        return None, missing
                if h is not None:
                    extra[d] = h
                else:
                    missing.add(d)
            return extra, missing

        def move_cost(cid: int, wid: int) -> int:
            """Bytes-weighted cost of running super-task ``cid`` on
            ``wid``.  A published value costs half (one consumer-side
            materialization); an unpublished remote value costs its full
            size (publish + materialize) — and every byte whose nearest
            copy lives on another *host* counts double, so both the
            stealing loop and the speculation twin pick prefer same-host
            shm moves over cross-host TCP pulls."""
            host = workers[wid].host
            cost = 0
            for d in plan.ext_deps[cid]:
                if store.has_replica(d, wid):
                    continue
                size = store.sizes.get(d, 0)
                if d in store.handles or d in store.cache:
                    c = size // 2
                else:
                    c = size
                if not store.on_host(d, host) and d not in store.cache:
                    c *= 2          # nearest copy is on another machine
                cost += c
            return cost

        def try_dispatch(cid: int, w: _Worker) -> bool:
            """Assign READY super-task ``cid`` to worker ``w``; ship
            handles or request publication of whatever remote inputs it
            needs.  Returns False when a recovery ran underneath (caller
            must re-snapshot the ready set)."""
            extra, missing = build_extra(cid, w.wid)
            if extra is None:
                return False                    # serialization task error
            if missing:
                # a "done" dep with no live owner and no durable copy is a
                # lost value the death handler didn't see (e.g. GC raced a
                # transfer): recover it through lineage like any other loss
                unreachable = {
                    d for d in missing
                    if d not in fetching and alive_owner(d) is None}
                if unreachable:
                    state[cid] = READY
                    recompute_lost(unreachable, unreachable, None)
                    return False
                state[cid] = WAITING
                waiting[cid] = (w.wid, missing)
                w.assigned.add(cid)
                for d in missing:
                    if d not in fetching:
                        ow = alive_owner(d)     # non-None: checked above
                        post(workers[ow], ("fetch", d))
                        fetching[d] = ow
                return True
            launch(cid, w, extra)
            return True

        def launch(cid: int, w: _Worker, extra: Dict[int, Any],
                   speculative: bool = False) -> None:
            """Queue the run message (flushed with the iteration's batch).
            If the worker dies before the flush lands, the death handler
            re-queues ``cid`` like any other in-flight loss."""
            state[cid] = INFLIGHT
            if resident:
                j = job_of(cid)
                if j is not None and j.first_dispatch is None:
                    j.first_dispatch = time.perf_counter()  # SLO: queue wait
            w.inflight.add(cid)
            runners.setdefault(cid, set()).add(w.wid)
            run_started.setdefault(cid, {})[w.wid] = time.perf_counter()
            if speculative:
                spec_twins.setdefault(cid, set()).add(w.wid)
                stats["n_speculative"] += 1
            post(w, ("run", cid, extra))
            stats["dispatched"] += 1
            for h in extra.values():
                account_transfer(h)

        def finish_waiting(cid: int) -> None:
            """All transfers for a WAITING super-task arrived — launch."""
            wid, _ = waiting.pop(cid)
            w = workers[wid]
            w.assigned.discard(cid)
            if not w.alive:
                state[cid] = READY
                return
            extra, missing = build_extra(cid, wid)
            if extra is None:
                return                  # serialization task error
            if missing:                 # a handle vanished under us (GC /
                state[cid] = READY      # racing recovery): re-dispatch
                return
            launch(cid, w, extra)

        def stealable(cid: int) -> bool:
            """A super-task may run off-plan only when its planned home
            cannot take it now (dead, or pipeline full) — stealing exists
            for stragglers, not for letting the first worker vacuum the
            whole ready set before its peers get a dispatch turn."""
            ow = plan_worker.get(cid)
            if ow is None or ow not in workers:
                return True
            home = workers[ow]
            return not dispatchable(home) \
                or home.load() >= self.pipeline_depth

        def dispatchable(w: _Worker) -> bool:
            """No NEW work for a worker under suspicion (its channel is
            silent — a dispatch would just park behind the partition) or in
            quarantine (it drains existing work while being probed)."""
            return (w.alive and w.wid not in suspects
                    and w.wid not in quarantined)

        def dispatch() -> None:
            ready = [c for c, s in state.items() if s == READY]
            if not ready:
                return
            if resident and len(jobs) > 1:
                # multi-tenant fairness tier: deficit-weighted round-robin
                # across tenants BEFORE the locality/stealing loop below,
                # so one tenant's wide high-rank graph cannot starve
                # another's short interactive job out of dispatch slots
                ready = fair_interleave(
                    ready,
                    lambda c: (job_of(c).tenant
                               if job_of(c) is not None else ""),
                    key=lambda c: (-rank[c], c),
                    weights=self._tenant_weights or None)
            else:
                ready.sort(key=lambda c: (-rank[c], c))
            for w in list(workers.values()):
                if not dispatchable(w):
                    continue
                while w.load() < self.pipeline_depth and ready:
                    # locality-aware choice: among this worker's planned
                    # tasks (or, stealing, the stealable ready window) run
                    # the one needing the fewest remote input bytes
                    window = ready[:32]
                    planned = [c for c in window
                               if plan_worker.get(c, w.wid) == w.wid]
                    pool = planned or [c for c in window if stealable(c)]
                    if not pool:
                        break       # everything here belongs to live peers
                    mine = min(pool, key=lambda c: (move_cost(c, w.wid),
                                                    -rank[c], c))
                    if not planned:
                        stats["steals"] += 1   # off-plan work
                    ready.remove(mine)
                    if state.get(mine) != READY:
                        continue    # demoted since the snapshot
                    if not try_dispatch(mine, w):
                        return      # recovery invalidated the snapshot

        def maybe_gc(tid: int) -> None:
            # a resident run GCs like outputs_only: every job's required
            # values sit in graph.outputs (collection-protected), so only
            # true intermediates of outputs_only jobs ever drain to zero
            if not (self.outputs_only or resident) \
                    or not store.collectable(tid):
                return
            for wid in list(store.locations(tid)):
                if wid in workers and workers[wid].alive:
                    post(workers[wid], ("drop", [tid]))
            store.invalidate({tid})     # also unlinks its shm segments
            store.mark_dropped(tid)     # late duplicate publishes: sweep
            relay_handles.pop(tid, None)
            stats["dropped"] += 1
            if runlog is not None:
                runlog.append("gc", [tid])

        def runner_gone(cid: int, wid: int) -> Optional[float]:
            """Bookkeeping when ``wid`` stops running ``cid`` (done,
            cancelled, deplost, or death).  Returns its dispatch time."""
            rs = runners.get(cid)
            if rs is not None:
                rs.discard(wid)
                if not rs:
                    runners.pop(cid, None)
            starts = run_started.get(cid)
            st = starts.pop(wid, None) if starts else None
            if starts is not None and not starts:
                run_started.pop(cid, None)
            return st

        def still_running(cid: int) -> bool:
            """True while a live worker is (believed to be) executing
            ``cid`` — dead runners were already discarded by their death
            handler, but guard against re-entrancy mid-handling."""
            return any(x in workers and workers[x].alive
                       for x in runners.get(cid, ()))

        def on_done(w: _Worker, cid: int, wall: float,
                    sizes: Dict[int, int],
                    replicated: Sequence[int]) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(cid)
            runner_gone(cid, w.wid)
            j = job_of(cid)
            if j is not None and j.terminal:
                # the job was already collected/failed and its id range
                # retired: whatever this late run materialized is residue
                # to sweep on the worker, never tracking to resurrect
                sweep = list(sizes) + list(replicated)
                if sweep and w.alive:
                    post(w, ("drop", sweep))
                return
            if state.get(cid) == DONE:
                # late duplicate: a speculation loser that kept executing
                # after the winner, or a replay raced by recovery.  Purity
                # makes the values identical, so each publish (the kept
                # members AND the transfer inputs the loser materialized)
                # either reconciles as a legitimate extra replica or —
                # when the GC already swept that value — is swept on this
                # worker too (it must not hold a value the driver thinks
                # is gone everywhere)
                sweep: List[int] = []
                swept_result = False
                for m in sizes:
                    if store.was_dropped(m):
                        sweep.append(m)
                        swept_result = True
                    else:
                        store.record_replica(m, w.wid)
                if swept_result:
                    stats["speculative_swept"] += 1
                for d in replicated:
                    if state.get(plan.cluster_of[d]) != DONE:
                        continue
                    if store.was_dropped(d):
                        sweep.append(d)
                    else:
                        store.record_replica(d, w.wid)
                if sweep and w.alive:
                    post(w, ("drop", sweep))
                stats["speculative_wasted_s"] += wall
                return
            # record transfer replicas first, so GC drops reach them too;
            # skip deps a racing recovery has invalidated (stale-but-pure
            # copies are harmless, but must not resurrect tracking state)
            for d in replicated:
                if state.get(plan.cluster_of[d]) == DONE:
                    store.record_replica(d, w.wid)
            state[cid] = DONE
            done.add(cid)
            finish_times[cid] = time.perf_counter() - t0
            for m, nb in sizes.items():
                store.record(m, w.wid, nb)
            if runlog is not None:
                # one delta record per completion — the incremental
                # checkpoint: O(cluster outputs), not O(workers) or O(graph)
                runlog.append("done", cid, w.wid, dict(sizes))
                # BARRIER values are the paper's lineage cut: pull them to
                # the driver so the log holds a durable copy even if every
                # replica dies with the outage
                for m in sizes:
                    if graph.nodes[m].kind is TaskKind.BARRIER \
                            and m not in fetching and not store.durable(m):
                        post(w, ("fetch", m))
                        fetching[m] = w.wid
            w.n_done += 1
            # runtime calibration of the static cost model (the launchers'
            # 0.9/0.1 straggler EWMA): seconds of wall per planned cost
            # unit, plus per-fn rates and the replayable run trace
            members = plan.members.get(cid, (cid,))
            cost_model.observe(
                planned_dur.get(cid, 1.0), wall,
                fn_units=[(fn_key(graph.nodes[m]), graph.nodes[m].cost)
                          for m in members if m in graph.nodes])
            trace.record(members, graph.nodes, wall)
            stats["cost_unit_s"] = cost_model.unit_s or 0.0
            maybe_refuse()
            # winner election: this completion wins; every other runner of
            # cid gets an idempotent cancel (honored between tasks — one
            # mid-task keeps going and late-dones into the branch above)
            if cid in spec_twins:
                if w.wid in spec_twins[cid]:
                    stats["speculative_wins"] += 1
                spec_twins.pop(cid, None)
            for owid in sorted(runners.get(cid, ())):
                ow = workers.get(owid)
                if ow is not None and ow.alive:
                    post(ow, ("cancel", cid))
            for d in plan.ext_deps[cid]:
                store.consumed(d)
                maybe_gc(d)
            for s in csucc[cid]:
                if state[s] == PENDING and \
                        all(state[d] == DONE for d in cg.nodes[s].all_deps):
                    state[s] = READY
            if self.fail_worker and w.wid == self.fail_worker[0] \
                    and w.n_done >= self.fail_worker[1] and w.alive:
                kill(w)
            nonlocal join_after
            if join_after and len(done) >= join_after[0]:
                n_new, join_after = join_after[1], None
                for _ in range(n_new):
                    join_one()

        def kill(w: _Worker) -> None:
            """SIGKILL + immediate failure handling (used by injection and
            the kill_worker command; organic deaths arrive via the
            channel).  A remote worker has no local pid to signal, so it
            is told to ``die`` — the executioner's message, then the same
            death handling."""
            if w.proc is not None:
                try:
                    os.kill(w.proc.pid, signal.SIGKILL)
                    w.proc.join(timeout=5.0)
                except (ProcessLookupError, OSError):
                    pass
            else:
                try:
                    w.chan.send(("die",))
                except ChannelClosed:
                    pass
            on_worker_death(w)

        def join_one(adopted: Optional[_Worker] = None) -> _Worker:
            w = adopted if adopted is not None else spawn()
            stats["joins"] += 1
            make_plan(initial=False)
            return w

        def recompute_lost(needed: Set[int], lost: Set[int],
                           cause: Any) -> None:
            """Lineage recovery at super-task granularity: re-run the
            minimal set of *clusters* that rebuilds the ``needed`` lost
            values, then replan onto the live workers."""
            available = store.available(set(alive_ids()))
            cplan = recovery_plan_clusters(plan, needed, available)
            stats["recomputed"] += len(cplan)
            self.recovery_events.append({
                "worker": cause, "lost": set(lost), "needed": set(needed),
                "available": set(available), "plan": set(cplan),
            })
            if runlog is not None and cplan:
                # retract the frontier claims (and any GC marks) the
                # re-runs invalidate, so a later resume sees them as open
                runlog.append("redo", sorted(cplan))
                runlog.append("live", sorted(
                    v for c in cplan for v in plan.members[c]))

            will_run = cplan | {c for c, s in state.items()
                                if s not in (DONE, CANCELLED)}
            vals = {v for c in cplan for v in plan.members[c]}
            store.invalidate(vals)
            for v in vals:      # a recomputed value gets a fresh handle
                relay_handles.pop(v, None)
            store.reset_consumers(cplan, will_run)
            for c in cplan:
                done.discard(c)
                finish_times.pop(c, None)
                # a recomputed incarnation starts fresh: old twin identity
                # must not misattribute its completion as a speculative win
                spec_twins.pop(c, None)
            # WAITING super-tasks elsewhere may block on a lost value:
            # reset them
            for cid in list(waiting):
                wid, need = waiting[cid]
                if need & vals:
                    waiting.pop(cid)
                    workers[wid].assigned.discard(cid)
                    state[cid] = READY
            for c in cplan:
                state[c] = (READY if all(state[d] == DONE
                                         for d in cg.nodes[c].all_deps)
                            else PENDING)
            # demote READY super-tasks whose deps just un-completed
            for cid, s in list(state.items()):
                if s == READY and any(state[d] != DONE
                                      for d in cg.nodes[cid].all_deps):
                    state[cid] = PENDING

            if not alive_ids():
                error.append(RuntimeError(
                    "cluster lost every worker; cannot recover"))
                return
            make_plan(initial=False)       # replan onto the survivors

        def on_worker_death(w: _Worker) -> None:
            nonlocal last_progress
            if not w.alive:
                return
            last_progress = time.perf_counter()
            w.alive = False
            w.chan.close()
            w.outbox.clear()
            suspects.pop(w.wid, None)
            flake_score.pop(w.wid, None)
            quarantined.pop(w.wid, None)
            stats["failures"] += 1
            if runlog is not None:
                runlog.append("dead", w.wid)

            # super-tasks that never completed there simply go back in the
            # pool — with two speculation exceptions: a SIGKILL of the
            # original while a twin still runs must NOT re-queue (the
            # survivor owns the task; re-queueing would be a double
            # recovery), and a loser that died while running an
            # already-DONE task is just wasted work, accounted, forgotten
            death_t = time.perf_counter()
            for cid in list(w.inflight):
                st = runner_gone(cid, w.wid)
                if state.get(cid) in (DONE, CANCELLED):
                    if st is not None:
                        stats["speculative_wasted_s"] += death_t - st
                    continue
                if still_running(cid):
                    continue            # a live twin/original has it
                state[cid] = READY
            w.inflight.clear()
            for cid in list(w.assigned):
                waiting.pop(cid, None)
                if state.get(cid) != CANCELLED:
                    state[cid] = READY
            w.assigned.clear()

            # values whose LAST copy lived in its store are lost -> lineage
            # (replicas / shm-published handles / driver cache survive)
            lost = store.drop_worker(w.wid)
            # fetches sent to the dead worker never reply: re-aim them at a
            # surviving replica, or let the recovery below reset the waiters
            for d, target in list(fetching.items()):
                if target != w.wid:
                    continue
                fetching.pop(d, None)
                if d in lost:
                    continue               # recovery resets its waiters
                ow = alive_owner(d)
                if ow is not None:
                    post(workers[ow], ("fetch", d))
                    fetching[d] = ow
            if self.outputs_only or resident:
                needed = {t for t in lost
                          if t in graph.outputs
                          or store.consumers_left.get(t, 0) > 0}
            else:
                needed = set(lost)
            recompute_lost(needed, lost, w.wid)

        def on_value(w: _Worker, tid: int, found: bool, handle: Any) -> None:
            nonlocal last_progress
            last_progress = time.perf_counter()
            fetching.pop(tid, None)
            j = job_of(tid)
            if j is not None and j.terminal:
                if found:       # retired value: free the stale segments
                    serde.release(handle)
                return
            owner_done = state.get(plan.cluster_of[tid]) == DONE
            if not found:
                # owner dropped/lost it between request and reply; try a
                # surviving replica, else recover like a partial failure
                if owner_done and not store.durable(tid):
                    ow = alive_owner(tid)
                    if ow is not None:
                        post(workers[ow], ("fetch", tid))
                        fetching[tid] = ow
                        return
                    store.invalidate({tid})
                    recompute_lost({tid}, {tid}, None)
                return
            if not owner_done:
                # a recovery invalidated tid while this reply was in flight:
                # the recompute supersedes it; free the stale segments
                serde.release(handle)
                return
            account_pipe(handle)
            store.set_handle(tid, handle)
            if runlog is not None:
                if serde.is_durable(handle):
                    # tmpfs/inline handles survive a driver death in place:
                    # the log only needs the pointer
                    runlog.append("hnd", tid,
                                  pickle.dumps(handle, protocol=5))
                elif graph.nodes[tid].kind is TaskKind.BARRIER:
                    # barrier value behind a worker-lifetime handle: spill
                    # the bytes themselves — the lineage cut must hold even
                    # if the whole pool dies with the driver
                    try:
                        runlog.append("val", tid, pickle.dumps(
                            serde.resolve(handle), protocol=5))
                    except Exception:       # noqa: BLE001 — best-effort
                        pass
            for c in list(waiting):
                entry = waiting.get(c)
                if entry is None:     # popped by a recovery mid-loop
                    continue
                _, need = entry
                need.discard(tid)
                if not need:
                    finish_waiting(c)

        def on_deplost(w: _Worker, cid: int, deps: Sequence[int]) -> None:
            """A dispatched super-task's input handles would not resolve
            (owner died mid-transfer / GC raced): re-queue the super-task
            and recover any input that is genuinely gone."""
            nonlocal last_progress
            last_progress = time.perf_counter()
            stats["deplosts"] += 1
            w.inflight.discard(cid)
            runner_gone(cid, w.wid)
            j = job_of(cid)
            if j is not None and j.terminal:
                return          # retired job: nothing to requeue/recover
            if state.get(cid) == DONE:
                # a speculation loser lost the race to the winner AND its
                # input handles to the winner-triggered GC sweep: nothing
                # is actually lost (a dep a live consumer still needs
                # surfaces through that consumer's own fetch/deplost)
                return
            if state.get(cid) == INFLIGHT and not still_running(cid):
                state[cid] = READY
            bad = {d for d in deps
                   if state.get(plan.cluster_of[d]) == DONE
                   and not store.durable(d)
                   and alive_owner(d) is None}
            # graceful degradation (docs/faults.md): a dep whose owner is
            # STILL ALIVE reached us because the worker's peer-fetch retries
            # exhausted (flaky data plane), not because the value is gone.
            # The driver resolves the handle itself and relays it inline on
            # the next dispatch — recompute stays reserved for real losses.
            for d in deps:
                if d in bad or d in relay_handles \
                        or state.get(plan.cluster_of[d]) != DONE:
                    continue
                if d in store.cache:
                    val = store.cache[d]
                else:
                    h = store.handles.get(d)
                    if h is None:
                        continue    # unpublished: re-dispatch re-fetches
                    try:
                        val = serde.resolve(h)
                    except serde.TransferLost:
                        if not store.durable(d) and alive_owner(d) is None:
                            bad.add(d)      # driver can't reach it either
                        continue
                    store.cache_value(d, val)
                try:
                    relay_handles[d] = serde.encode(
                        val, transport="driver",
                        threshold=self.shm_threshold)
                except Exception:   # noqa: BLE001 — unshippable inline:
                    continue        # leave the direct path in place
                stats["relay_fallbacks"] += 1
            if bad:
                store.invalidate(bad)
                recompute_lost(bad, bad, None)
            # inputs may themselves be mid-recompute (an earlier recovery):
            # wait for them instead of re-triggering loss detection
            if state.get(cid) == READY and any(
                    state.get(d) != DONE
                    for d in cg.nodes[cid].all_deps):
                state[cid] = PENDING

        def on_cancelled(w: _Worker, cid: int,
                         replicated: Sequence[int] = (),
                         wall: float = 0.0) -> None:
            """The worker honored a cancel mark on ``cid`` — either before
            starting (3-tuple ack) or cooperatively at a member boundary
            mid-super-task (extended ack, carrying the transfer inputs it
            had already materialized and the partial wall it burned).
            Normally the winner already completed (nothing to do); if the
            mark was stale — a lineage-recovery re-dispatch raced a cancel
            from a previous incarnation — the run was still wanted, so the
            super-task goes back in the pool."""
            nonlocal last_progress
            last_progress = time.perf_counter()
            w.inflight.discard(cid)
            runner_gone(cid, w.wid)
            j = job_of(cid)
            if j is not None and j.terminal:
                return      # cancelled-job ack: bookkeeping already gone
            # inputs an aborted run stored are real replicas (or, already
            # GC-swept, residue to sweep on this worker too) — same
            # reconciliation as a late duplicate done
            sweep: List[int] = []
            for d in replicated:
                if state.get(plan.cluster_of[d]) != DONE:
                    continue
                if store.was_dropped(d):
                    sweep.append(d)
                else:
                    store.record_replica(d, w.wid)
            if sweep and w.alive:
                post(w, ("drop", sweep))
            if state.get(cid) == DONE:
                # a mid-task abort of a speculation loser: the partial wall
                # is the true waste (the pre-abort fix charged the FULL
                # super-task duration, because the loser ran to completion)
                stats["speculative_wasted_s"] += wall
                return
            if state.get(cid) == INFLIGHT and not still_running(cid):
                state[cid] = READY

        def effective_speculate_after() -> Optional[float]:
            """Static ``speculate_after`` always wins; under adaptive
            mode an unset threshold is derived from the observed duration
            variance (docs/adaptive.md) — tight when durations are
            predictable, loose when natural spread is high."""
            if self.speculate_after is not None:
                return self.speculate_after
            if self.adaptive == "off":
                return None
            d = cost_model.derived_speculate_after()
            if d is not None:
                stats["adaptive_speculate_after"] = d
            return d

        def maybe_refuse() -> None:
            """Mid-run re-fusion (docs/adaptive.md): when measured
            durations are skewed enough that the static plan's grouping
            is evidently mis-costed, regroup the not-yet-dispatched
            frontier under profile-corrected costs.  Completed and
            in-flight clusters are pinned (they are simply not in the
            frontier); the decision is journaled so a resumed driver
            replays it bit-identically.  Disabled for resident (gateway)
            runs — job id spans pin cluster ids — and after any
            recovery: a post-outage run values plan stability over
            regrouping."""
            nonlocal n_total, rank, csucc
            if (self.adaptive == "off" or resident or plan.identity
                    or error or self.recovery_events
                    or stats["recomputed"]):
                return
            cost_model.observe_dispatch(
                stats["dispatch_overhead_s"], stats["dispatched"])
            stats["dispatch_cost_s"] = cost_model.dispatch_s
            frontier = [c for c, s in state.items()
                        if s in (PENDING, READY)]
            if not refusion_due(cost_model, governor, len(frontier)):
                return
            stats["adaptive_skew"] = governor.last_skew
            gates = cost_model.fuse_gates(DEFAULT_FANIN_COST,
                                          DEFAULT_GROUP_COST)
            kp_live = self.keep_parallelism or max(
                DEFAULT_KEEP_PARALLELISM, 2 * len(alive_ids()))
            res = refuse_frontier(
                plan, frontier, spec=self.fuse,
                cost_of=cost_model.corrected_units,
                fanin_cost=gates[0], group_cost=gates[1],
                keep_parallelism=kp_live)
            if res is None:
                governor.note_no_change(cost_model)
                return
            retired, new_clusters = res
            delta = splice_plan(plan, retired, new_clusters)
            # store refcounts follow the consumer-set delta (frontier
            # consumers never ran, so no completed decrement is disturbed
            # and no count can reach zero here)
            for v, d in delta.items():
                store.consumers_left[v] = \
                    store.consumers_left.get(v, 0) + d
            for c in retired:
                state.pop(c, None)
                planned_dur.pop(c, None)
                plan_worker.pop(c, None)
                fusion_view.members.pop(c, None)
                fusion_view.keep.pop(c, None)
            # new_clusters is topo-ordered, so a new cluster's new-cluster
            # deps are already in ``state`` when it is seeded
            view_delta: Dict[str, Dict] = {"members": {}, "keep": {}}
            for cid, ms in new_clusters:
                node = cg.nodes[cid]
                state[cid] = (READY if all(state[d] == DONE
                                           for d in node.all_deps)
                              else PENDING)
                planned_dur[cid] = max(node.cost, 1e-6)
                # keep rule mirrors FusedPlan.worker_view
                keep = tuple(m for m in ms
                             if m in required or m in plan._outset[cid])
                fusion_view.members[cid] = tuple(ms)
                fusion_view.keep[cid] = keep
                view_delta["members"][cid] = tuple(ms)
                view_delta["keep"][cid] = keep
            n_total += len(new_clusters) - len(retired)
            rank = cg.critical_path_rank()
            csucc = cg.successors()
            # live workers learn the new memberships before any dispatch
            # of a new cid can reach them (same FIFO outbox); retired ids
            # are never dispatched again, so their stale entries on the
            # worker are inert.  Late joiners get the mutated fusion_view
            # in their welcome config.
            blob = pickle.dumps(view_delta,
                                protocol=pickle.HIGHEST_PROTOCOL)
            for lw in workers.values():
                if lw.alive:
                    post(lw, ("graph", blob))
            if runlog is not None:
                runlog.append("refuse", tuple(retired),
                              tuple((cid, tuple(ms))
                                    for cid, ms in new_clusters))
            governor.note_fired(cost_model)
            stats["refusions"] += 1
            stats["n_clusters"] = len(cg.nodes)
            stats["tasks_fused"] = plan.n_fused
            make_plan(initial=False)

        def maybe_speculate() -> None:
            """Speculative re-execution of stragglers: duplicate the
            most-overdue running super-task onto an idle worker.  Runs
            only when no READY work exists anywhere (twins never displace
            first executions) and only after the first completion
            calibrated the cost model into seconds.  The *pick* is
            :func:`repro.core.simulator.pick_speculation` — the
            simulator's policy, verbatim; the *placement* is
            locality-aware: among idle workers, the twin runs where its
            input bytes are cheapest (``move_cost`` doubles bytes whose
            nearest copy is on another host, so an idle same-host worker
            beats a cross-host one)."""
            spec_after = effective_speculate_after()
            if spec_after is None or cost_model.unit_s is None:
                return
            if any(s == READY for s in state.values()):
                return
            idle = [w for w in workers.values()
                    if dispatchable(w) and w.load() == 0]
            if not idle:
                return
            now = time.perf_counter()
            overdue_view: Dict[int, Tuple[float, float]] = {}
            for cid, wids in runners.items():
                if state.get(cid) != INFLIGHT or len(wids) != 1:
                    continue                # done, or already twinned
                (rw,) = tuple(wids)
                st = run_started.get(cid, {}).get(rw)
                if st is None:
                    continue
                expected = planned_dur.get(cid, 1.0) * cost_model.unit_s
                overdue_view[cid] = (now - st, max(expected, 1e-9))
            while idle and overdue_view:
                cid = pick_speculation(overdue_view, spec_after)
                if cid is None:
                    return
                elapsed, _ = overdue_view.pop(cid)
                w = min(idle, key=lambda iw: (move_cost(cid, iw.wid),
                                              iw.wid))
                extra, missing = build_extra(cid, w.wid)
                if extra is None:
                    return              # serialization error surfaced
                if missing:
                    continue            # inputs not shippable now; a
                    # twin is opportunistic — never fetch-block for one
                primary = next(iter(runners.get(cid, {-1})))
                self.speculation_events.append(
                    {"tid": cid, "primary": primary, "twin": w.wid,
                     "t": now - t0, "elapsed": elapsed})
                launch(cid, w, extra, speculative=True)
                idle.remove(w)

        def handle_msg(w: _Worker, msg: tuple) -> None:
            verb = msg[0]
            if verb == "done":
                on_done(w, msg[2], msg[3], msg[4], msg[5])
            elif verb == "value":
                on_value(w, msg[2], msg[3], msg[4])
            elif verb == "value_many":
                for tid, found, handle in msg[2]:
                    if not w.alive:
                        break   # death handler ran under an earlier entry
                    on_value(w, tid, found, handle)
            elif verb == "deplost":
                on_deplost(w, msg[2], msg[3])
            elif verb == "cancelled":
                # 3-tuple: skipped while queued; 5-tuple: aborted at a
                # member boundary mid-run (replicated inputs + partial wall)
                on_cancelled(w, msg[2], *(msg[3:5] if len(msg) > 3 else ()))
            elif verb == "fetch_error":
                # a fetch reply that could not be serialized names a VALUE
                # tid, not a super-task: the value cannot be collected, so
                # the run fails — but no cluster bookkeeping may run on an
                # id from the wrong namespace
                tid = msg[2]
                fetching.pop(tid, None)
                node = graph.nodes.get(tid)
                task_error(tid, TaskFailed(
                    tid, node.name if node else f"#{tid}",
                    RuntimeError(f"{msg[3]}: {msg[4]}")))
            elif verb == "error":
                cid = msg[2]
                w.inflight.discard(cid)
                was_runner = w.wid in runners.get(cid, ())
                runner_gone(cid, w.wid)
                j = job_of(cid)
                if msg[3] == "MissingInput":
                    # caller-error contract: never wrapped in TaskFailed.
                    # A job's message carries its namespaced placeholder
                    # ("j3/x"): report it in the submitter's vocabulary
                    if j is not None:
                        fail_job(j, MissingInput(
                            msg[4].replace(f"j{j.job_id}/", "")))
                    else:
                        error.append(MissingInput(msg[4]))
                elif state.get(cid) in (DONE, CANCELLED) and was_runner:
                    # a speculation loser failing AFTER the winner (e.g.
                    # its inputs were GC-swept under the race) must not
                    # abort a run whose result already exists.  Only
                    # *execution* duplicates reach here — fetch-reply
                    # failures arrive as fetch_error and stay fatal
                    pass
                else:
                    node = cg.nodes.get(cid)
                    task_error(cid, TaskFailed(
                        cid, node.name if node else f"#{cid}",
                        RuntimeError(f"{msg[3]}: {msg[4]}")))
            elif verb in ("hb", "bye"):
                pass        # liveness bookkeeping happens in the channel

        def pump(timeout: float) -> None:
            flush_all()     # batched sends hit the wire before we sleep
            chans = {w.chan.selectable(): w
                     for w in workers.values() if w.alive}
            if not chans:
                return
            drained: Set[int] = set()
            for sel in conn_wait(list(chans), timeout=timeout):
                w = chans[sel]
                drained.add(w.wid)
                try:
                    msgs = w.chan.recv_available()
                except ChannelClosed:
                    on_worker_death(w)
                    continue
                stats["control_msgs"] += len(msgs)
                for msg in msgs:
                    if not w.alive:
                        break       # death handler ran under an earlier msg
                    handle_msg(w, msg)
            # a fault wrapper may hold parked frames whose release time
            # passed with NO new wire bytes — conn_wait never reports those
            # channels readable, so drain them explicitly
            for w in list(workers.values()):
                if not w.alive or w.wid in drained:
                    continue
                if not getattr(w.chan, "has_ready", lambda: False)():
                    continue
                msgs = w.chan.drain_ready()
                stats["control_msgs"] += len(msgs)
                for msg in msgs:
                    if not w.alive:
                        break
                    handle_msg(w, msg)

        def collect_values(req: Set[int]) -> bool:
            """Materialize ``req`` values into the driver cache — decoding
            published handles directly (no control traffic), fetching
            handles for the rest.  Returns True when everything in ``req``
            is cached.  Used for a single-graph run's finals AND for each
            resident-mode job's independent gather."""
            nonlocal last_progress
            missing = [t for t in req if t not in store.cache]
            if not missing:
                return True
            # one bulk fetch per owner: the per-value fetch/value ping-pong
            # collapses into a fetch_many/value_many round-trip per worker
            by_owner: Dict[int, List[int]] = {}
            for t in missing:
                h = store.handles.get(t)
                if h is not None:
                    try:
                        value = serde.resolve(h)
                    except serde.TransferLost:
                        store.invalidate({t})
                        recompute_lost({t}, {t}, None)
                        return False
                    store.cache_value(t, value)
                    d = serde.direct_nbytes(h)
                    if d > 0:
                        stats["bytes_direct"] += d
                        stats["bytes_moved"] += d
                        stats["transfers_direct"] += 1
                    last_progress = time.perf_counter()
                    continue
                if t in fetching:
                    continue
                ow = alive_owner(t)
                if ow is None:
                    store.invalidate({t})
                    recompute_lost({t}, {t}, None)
                    return False
                by_owner.setdefault(ow, []).append(t)
                fetching[t] = ow
            for ow, tids in by_owner.items():
                post(workers[ow], ("fetch_many", tids))
            return not [t for t in req if t not in store.cache]

        def collect_finals() -> bool:
            return collect_values(required)

        # ------------------------------------------------ resident-mode jobs
        def admit_job(job: _Job) -> None:
            """Splice an offset job into the live union run: graph nodes,
            plan maps, fusion view, refcount universe, scheduler state —
            then fan the delta out to every adopted worker (the outbox is
            FIFO, so the delta lands before any run that needs it; later
            joiners receive the merged graph in their welcome/fork)."""
            nonlocal n_total
            jp = job.plan
            jview = jp.worker_view(job.required)
            try:
                delta = pickle.dumps(
                    {"nodes": jp.graph.nodes, "inputs": job.inputs,
                     "members": jview.members, "keep": jview.keep},
                    protocol=5)
            except Exception as e:      # noqa: BLE001 — job-fatal only
                job.terminal = True
                job.future._set_error(ValueError(
                    "job graph is not picklable, so it cannot be shipped "
                    "to the pool's workers (use module-level task "
                    f"functions): {e!r}"))
                return
            graph.nodes.update(jp.graph.nodes)
            # required values are collection-protected from the GC the
            # same way a single-graph run protects its outputs
            graph.outputs.extend(sorted(job.required))
            inputs.update(job.inputs)
            cg.nodes.update(jp.cgraph.nodes)
            plan.members.update(jp.members)
            plan.cluster_of.update(jp.cluster_of)
            plan.outputs.update(jp.outputs)
            plan.ext_deps.update(jp.ext_deps)
            plan.consumers.update(jp.consumers)
            plan._outset.update(
                {c: set(vs) for c, vs in jp.outputs.items()})
            fusion_view.members.update(jview.members)
            fusion_view.keep.update(jview.keep)
            store.admit(jp.graph.nodes)
            rank.update(jp.cgraph.critical_path_rank())
            csucc.update(jp.cgraph.successors())
            for cid, node in jp.cgraph.nodes.items():
                state[cid] = READY if not node.all_deps else PENDING
                planned_dur[cid] = max(node.cost, 1e-6)
            n_total += len(jp.cgraph.nodes)
            stats["n_clusters"] += len(jp.cgraph.nodes)
            stats["tasks_fused"] += jp.n_fused
            stats["jobs_admitted"] += 1
            jobs[job.job_id] = job
            job_spans.append((job.base, job.end, job))
            span_starts.append(job.base)
            graph_blob[0] = None    # graph-less dialers need the union
            for w in workers.values():
                if w.alive:
                    post(w, ("graph", delta))
            if runlog is not None:
                runlog.append("job", job.job_id, {
                    "tenant": job.tenant, "base": job.base,
                    "end": job.end, "n_clusters": len(job.cids)})
            make_plan(initial=False)

        def retire_job(job: _Job) -> None:
            """Forget a finished/failed job everywhere, so a long-lived
            resident run's state does not grow with every job ever
            admitted.  Tombstones stay in ``state``/``plan.cluster_of``
            and the span index (small ints), so late worker messages
            about retired ids stay identifiable and inert."""
            jobs.pop(job.job_id, None)
            span = range(job.base, job.end)
            store.retire(span)
            for t in span:
                graph.nodes.pop(t, None)
                cg.nodes.pop(t, None)
                plan.members.pop(t, None)
                plan.outputs.pop(t, None)
                plan.ext_deps.pop(t, None)
                plan.consumers.pop(t, None)
                plan._outset.pop(t, None)
                fusion_view.members.pop(t, None)
                fusion_view.keep.pop(t, None)
                rank.pop(t, None)
                csucc.pop(t, None)
                planned_dur.pop(t, None)
                finish_times.pop(t, None)
                plan_worker.pop(t, None)
                done.discard(t)
                fetching.pop(t, None)
                relay_handles.pop(t, None)
                spec_twins.pop(t, None)
                entry = waiting.pop(t, None)
                if entry is not None:
                    ow = workers.get(entry[0])
                    if ow is not None:
                        ow.assigned.discard(t)
            graph.outputs = [o for o in graph.outputs
                             if not (job.base <= o < job.end)]
            for name in job.inputs:
                inputs.pop(name, None)
            graph_blob[0] = None
            delta = pickle.dumps(
                {"retire": tuple(span),
                 "retire_inputs": tuple(job.inputs)}, protocol=5)
            for w in workers.values():
                if w.alive:
                    post(w, ("graph", delta))

        def finish_job(job: _Job) -> None:
            """Every cluster of ``job`` is DONE and its required values
            are cached: resolve the future (keys in the SUBMITTER's id
            space), journal, and retire the id range."""
            job.terminal = True
            now = time.perf_counter()
            if job.coll_map is None:
                results = {t: store.cache[t + job.base]
                           for t in job.user_required}
            else:
                results = {t: store.cache[job.coll_map[t]]
                           for t in job.user_required}
            latency = now - job.submitted
            first = (job.first_dispatch - job.submitted
                     if job.first_dispatch is not None else latency)
            stats["jobs_completed"] += 1
            if runlog is not None:
                runlog.append("jobdone", job.job_id)
            job.future._set_result(
                results, wall_time=latency,
                stats={"tenant": job.tenant, "job_id": job.job_id,
                       "n_clusters": len(job.cids),
                       "submit_to_first_dispatch_s": first,
                       "submit_to_gather_s": latency,
                       # adaptive observability: the run-wide calibrated
                       # rates this job executed under (re-fusion itself
                       # is disabled for resident runs)
                       "cost_unit_s": cost_model.unit_s or 0.0,
                       "dispatch_cost_s": cost_model.dispatch_s,
                       "adaptive_speculate_after":
                           stats["adaptive_speculate_after"]})
            retire_job(job)

        def fail_job(job: _Job, exc: BaseException) -> None:
            """Tenant isolation: one job's task failure (or cancellation)
            fails ONLY that job's future.  Its unfinished clusters become
            CANCELLED (terminal — dispatch skips them, recovery never
            resurrects them), in-flight runs get idempotent cancel marks,
            and the id range is retired.  Every other tenant's work is
            untouched; ``error`` stays reserved for infrastructure-fatal
            conditions (pool lost, progress timeout)."""
            if job.terminal:
                return
            job.terminal = True
            stats["jobs_failed"] += 1
            for cid in job.cids:
                s = state.get(cid)
                if s == DONE:
                    continue
                state[cid] = CANCELLED
                if s == INFLIGHT:
                    for owid in sorted(runners.get(cid, ())):
                        ow = workers.get(owid)
                        if ow is not None and ow.alive:
                            post(ow, ("cancel", cid))
                elif s == WAITING:
                    entry = waiting.pop(cid, None)
                    if entry is not None:
                        ow = workers.get(entry[0])
                        if ow is not None:
                            ow.assigned.discard(cid)
            if runlog is not None:
                runlog.append("jobdone", job.job_id)
            job.future._set_error(exc)
            retire_job(job)

        def service_jobs() -> None:
            """Resident-mode completion scan: collect and resolve every
            job whose clusters are all DONE.  Each job gathers
            independently — one tenant's transfer stall never blocks
            another tenant's result."""
            for job in list(jobs.values()):
                if job.terminal or error:
                    continue
                if all(state.get(c) == DONE for c in job.cids):
                    if collect_values(job.required):
                        finish_job(job)

        def check_commands() -> None:
            with self._cmd_lock:
                cmds, self._commands = self._commands, []
            for cmd in cmds:
                if cmd[0] == "join":
                    join_one()
                elif cmd[0] == "kill" and cmd[1] in workers \
                        and workers[cmd[1]].alive:
                    kill(workers[cmd[1]])
                elif cmd[0] == "job":
                    if resident:
                        admit_job(cmd[1])
                    else:
                        cmd[1].future._set_error(RuntimeError(
                            "job submission requires a resident "
                            "executor (start_resident())"))
                elif cmd[0] == "canceljob" and resident:
                    cj = jobs.get(cmd[1])
                    if cj is not None:
                        fail_job(cj, cmd[2])
                elif cmd[0] == "logrec":
                    if runlog is not None:
                        runlog.append(*cmd[1])
            # a repro-worker dialing a live TCP run is an elastic join —
            # including dials parked in the stash while adopt_dialer_for
            # was pid-matching a local spawn (they would otherwise hang
            # unanswered until their handshake timeout)
            if listener is not None:
                while True:
                    pair = dial_stash.pop(0) if dial_stash \
                        else listener.poll_worker()
                    if pair is None:
                        break
                    if pair[1].get("rejoin") is not None:
                        # a surviving worker re-dialing after a driver
                        # socket drop (outage, partition heal): re-adopt
                        # in place, never as a fresh join
                        if adopt_rejoin(pair[0], pair[1]) is not None:
                            make_plan(initial=False)
                        continue
                    try:
                        join_one(adopt(pair[0], pair[1], proc=None))
                    except (ValueError, TimeoutError):
                        pass    # cross-host dial into a host-local
                        # transport, or the dialer died mid-welcome:
                        # a bad joiner must never take down the run

        def check_deaths() -> None:
            """Channel-based liveness, partition-aware (docs/faults.md).

            A *definitive* verdict (process exit, EOF, send failure) is a
            death, immediately.  A *silence* verdict (missed heartbeats)
            is first a SUSPICION: the worker is taken out of the dispatch
            rotation for up to ``suspect_grace`` seconds; if its frames
            return inside the window it heals — its in-flight bookkeeping
            was never torn down, so reconciliation is free and
            ``recomputed`` stays 0.  Only an expired grace escalates to
            the lineage-recovery death path.

            Healing is scored: ``quarantine_after`` suspect-then-heal
            episodes quarantine the worker (drain, no new dispatches), and
            ``probe_interval`` of verified-healthy channel re-admits it
            with its flakiness score halved."""
            now = time.perf_counter()
            for w in list(workers.values()):
                if not w.alive:
                    continue
                wid = w.wid
                verdict = w.chan.dead()
                if verdict is None:
                    if wid in suspects:
                        suspects.pop(wid)
                        stats["healed"] += 1
                        flake_score[wid] = flake_score.get(wid, 0.0) + 1.0
                        if wid in quarantined:
                            quarantined[wid] = now  # probe restarts
                        elif flake_score[wid] >= self.quarantine_after \
                                and any(x.alive and x.wid != wid
                                        and x.wid not in quarantined
                                        for x in workers.values()):
                            # never quarantine the last usable worker
                            quarantined[wid] = now
                            stats["quarantined"] += 1
                    elif wid in quarantined and \
                            now - quarantined[wid] >= self.probe_interval:
                        quarantined.pop(wid)
                        flake_score[wid] = flake_score.get(wid, 0.0) / 2.0
                        stats["readmitted"] += 1
                    continue
                if is_silence(verdict) and self.suspect_grace > 0:
                    first = suspects.get(wid)
                    if first is None:
                        suspects[wid] = now
                        stats["suspected"] += 1
                        continue
                    if now - first < self.suspect_grace:
                        continue        # still inside the grace window
                on_worker_death(w)

        # ------------------------------------------------------ driver resume
        # worker inventories reported at rejoin, parked until the frontier
        # is seeded (a rejoiner can't be reconciled against state that
        # doesn't exist yet); late rejoiners record directly
        inventories: Dict[int, List[Tuple[int, int]]] = {}
        resume_seeded = [rs is None]

        def adopt_rejoin(sock, hello: dict) -> Optional[_Worker]:
            """Re-adopt a surviving worker of THIS run: it keeps its old
            worker id and its object store; its inventory (first frame
            after the welcome) tells the driver what actually survived."""
            nonlocal next_wid
            wid = hello.get("wid")

            def refuse(reason: str) -> None:
                try:
                    _send_frame(sock, pickle.dumps(("reject", reason),
                                                   protocol=5))
                except OSError:
                    pass
                try:
                    sock.close()
                except OSError:
                    pass

            if hello.get("rejoin") != run_id:
                refuse(f"unknown run {hello.get('rejoin')!r}")
                return None
            if not isinstance(wid, int) or wid < 0:
                refuse(f"malformed rejoin wid {wid!r}")
                return None
            worker_host = hello.get("host", "?")
            try:
                _send_frame(sock, pickle.dumps(
                    ("welcome", wid, run_config(hello), None), protocol=5))
                sock.settimeout(10.0)
                first = _recv_frame(sock)
                sock.settimeout(None)
            except (OSError, EOFError, pickle.UnpicklingError,
                    ChannelClosed):
                try:
                    sock.close()
                except OSError:
                    pass
                return None
            if not (isinstance(first, tuple) and len(first) == 3
                    and first[0] == "inv"):
                try:
                    sock.close()
                except OSError:
                    pass
                return None
            inv = [(t, nb) for t, nb in first[2] if t in graph.nodes]
            chan = wrap_chan(
                TcpChannel(sock,
                           heartbeat_interval=self.heartbeat_interval,
                           heartbeat_timeout=self.heartbeat_timeout,
                           heartbeat_jitter=self.heartbeat_jitter),
                wid)
            old = workers.get(wid)
            if old is not None and old.alive:
                # same worker process re-dialed under a live driver (socket
                # bounce / healed partition): swap the transport, keep the
                # in-flight bookkeeping — its queued work continues there.
                # NOT a death: close must not trip the death handler
                old.chan.close()
                old.chan = chan
                w = old
                if wid in suspects:     # the re-dial IS the heal signal
                    suspects.pop(wid)
                    stats["healed"] += 1
                    flake_score[wid] = flake_score.get(wid, 0.0) + 1.0
            else:
                # driver-restart rejoin (or a worker whose heartbeat loss
                # was already recovered — its values are extra replicas
                # now, never a second recovery plan)
                w = _Worker(wid, chan, worker_host, proc=None)
                workers[wid] = w
                store.add_worker(wid, host=worker_host)
                next_wid = max(next_wid, wid + 1)
            if runlog is not None:
                runlog.append("worker", wid, worker_host)
            if not resume_seeded[0]:
                inventories[wid] = inv
            else:
                for t, nb in inv:
                    if state.get(plan.cluster_of[t]) == DONE \
                            and not store.was_dropped(t):
                        store.record(t, w.wid, nb)
            return w

        def rejoin_barrier() -> None:
            """Bounded wait for the interrupted run's surviving workers to
            re-dial the freshly rebound listener.  Workers that never show
            are simply absent — their values count as outage losses and
            lineage recovers them; nothing blocks on a corpse."""
            expected = set(rs.live_workers) - set(workers)
            deadline = time.monotonic() + self.rejoin_timeout
            while expected:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                heartbeat_all()
                try:
                    sock, hello = listener.get_worker(min(0.5, remaining))
                except TimeoutError:
                    continue
                if hello.get("rejoin") is not None:
                    w = adopt_rejoin(sock, hello)
                    if w is not None:
                        expected.discard(w.wid)
                else:
                    dial_stash.append((sock, hello))    # fresh dial: joins
                    # elastically once the run is seeded and live

        def seed_from_checkpoint() -> None:
            """Rebuild the execution frontier from the run log plus what
            rejoined workers actually report holding, then reconcile: every
            claimed-done value that truly survived stays done; everything
            else becomes ONE recovery plan (bounded recomputation)."""
            # durable copies the log recorded, existence-verified — a
            # checkpoint never outranks the filesystem
            live_handles: Dict[int, Any] = {}
            for tid, hb in rs.handles.items():
                if tid not in graph.nodes:
                    continue
                try:
                    h = pickle.loads(hb)
                except Exception:       # noqa: BLE001 — stale/foreign blob
                    continue
                if not serde.is_durable(h):
                    continue
                refs = getattr(h, "shm_refs", lambda: ())()
                if all(os.path.exists(os.path.join(serde._SHM_DIR, r.name))
                       for r in refs):
                    live_handles[tid] = h
            values: Dict[int, Any] = {}
            for tid, vb in rs.values.items():
                if tid not in graph.nodes:
                    continue
                try:
                    values[tid] = pickle.loads(vb)
                except Exception:       # noqa: BLE001
                    continue
            inv_tids = {t for inv in inventories.values() for t, _ in inv}
            survived = inv_tids | set(live_handles) | set(values)
            # the frontier: checkpoint claims, plus promotion of clusters
            # that finished during the outage window (claim lost with the
            # unflushed tail) but whose entire externally-visible keep set
            # demonstrably survived
            done0 = {cid for cid in rs.done if cid in cg.nodes}
            for cid in cg.nodes:
                if cid in done0:
                    continue
                ks = fusion_view.keep.get(cid) or plan.members[cid]
                if all(t in survived for t in ks):
                    done0.add(cid)
            store.seed_after_outage(done0, inventories, live_handles,
                                    values, rs.dropped)
            for cid, (_, sizes_) in rs.done.items():
                if cid in done0:
                    for t, nb in sizes_.items():
                        if nb:
                            store.sizes.setdefault(t, nb)
            for cid in done0:
                state[cid] = DONE
                done.add(cid)
                finish_times[cid] = 0.0     # completed in a past life
            for cid in cg.nodes:
                if cid in done0:
                    continue
                state[cid] = (READY if all(state.get(d) == DONE
                                           for d in cg.nodes[cid].all_deps)
                              else PENDING)
            resume_seeded[0] = True
            stats["resumed_clusters"] = len(done0)
            # reconcile claims against reality: all outage losses fold into
            # exactly ONE recovery plan — a worker whose heartbeat died
            # with the driver is part of this plan, never a second one
            available = store.available(set(alive_ids()))
            lost, needed, _ = outage_recovery(plan, graph, done0, available,
                                              self.outputs_only)
            if lost or needed:
                recompute_lost(needed, lost, "driver-outage")

        # ------------------------------------------------------- main loop
        self._active = True
        crashed = False
        try:
            if rs is not None:
                if listener is not None:
                    rejoin_barrier()
                n_live = len([w for w in workers.values() if w.alive])
                for _ in range(max(0, len(self.worker_specs) - n_live)):
                    spawn()
                seed_from_checkpoint()
                if not error:
                    make_plan(initial=False)
            else:
                for spec in self.worker_specs:
                    if spec == "remote":
                        adopt_remote()
                    else:
                        spawn()
                make_plan(initial=True)
            while not error:
                check_commands()
                if resident:
                    # the resident loop never "finishes": it services job
                    # completions and keeps dispatching until shut down
                    if self._shutdown.is_set():
                        break
                    service_jobs()
                    t_d = time.perf_counter()
                    dispatch()
                    maybe_speculate()
                    stats["dispatch_overhead_s"] += \
                        time.perf_counter() - t_d
                elif len(done) >= n_total:
                    if collect_finals():
                        break
                else:
                    t_d = time.perf_counter()
                    dispatch()
                    maybe_speculate()
                    stats["dispatch_overhead_s"] += \
                        time.perf_counter() - t_d
                pump(timeout=0.02)
                if runlog is not None:
                    runlog.maybe_flush()
                    if time.monotonic() - last_lease > 5.0:
                        for p in [seg_prefix] + old_prefixes:
                            serde.refresh_resume_lease(p)
                        last_lease = time.monotonic()
                if self.fail_driver is not None and not crashed \
                        and len(done) >= self.fail_driver:
                    # emulated kill -9: sockets and listener torn down raw,
                    # every shutdown nicety (stop/join/flush/sweep) skipped.
                    # Buffered log records since the last timed flush are
                    # LOST — exactly what a real SIGKILL loses
                    crashed = True
                    for w in workers.values():
                        if not w.alive:
                            continue
                        raw = getattr(w.chan, "sock", None) \
                            or getattr(w.chan, "conn", None)
                        try:
                            raw.close() if raw is not None \
                                else w.chan.close()
                        except OSError:
                            pass
                    if listener is not None:
                        listener.close()
                        self.listener = None
                    raise DriverKilled(run_id)
                check_deaths()
                for w in workers.values():
                    if w.alive:
                        w.chan.maybe_heartbeat()
                if resident and not jobs:
                    # an idle resident service is healthy, not hung: the
                    # progress watchdog only arms while jobs are admitted
                    last_progress = time.perf_counter()
                if time.perf_counter() - last_progress > self.progress_timeout:
                    by_state: Dict[int, List[int]] = {}
                    for c, s in state.items():
                        by_state.setdefault(s, []).append(c)
                    error.append(RuntimeError(
                        f"cluster made no progress for "
                        f"{self.progress_timeout}s "
                        f"(done {len(done)}/{n_total}, states "
                        f"{ {s: sorted(ts)[:8] for s, ts in by_state.items() if s != DONE} }, "
                        f"waiting {dict(list(waiting.items())[:4])}, "
                        f"fetching {dict(list(fetching.items())[:8])}, "
                        f"inflight {[sorted(w.inflight) for w in workers.values()]})"))
        finally:
            self._active = False
            if resident:
                # jobs the loop never resolved (shutdown mid-run, infra
                # error, pool bring-up failure) must not hang clients —
                # including submissions still parked in the command queue
                rexc = (error[0] if error
                        else RuntimeError("resident executor shut down"))
                for job in list(jobs.values()):
                    if not job.terminal:
                        job.terminal = True
                        job.future._set_error(rexc)
                with self._cmd_lock:
                    cmds, self._commands = self._commands, []
                for cmd in cmds:
                    if cmd[0] == "job":
                        cmd[1].future._set_error(rexc)
            if crashed:
                # emulated SIGKILL: leave everything exactly as a dead
                # driver would — workers alive (rejoin loops armed), shm
                # segments in place, run log unflushed past its last timed
                # fsync.  The resumed incarnation (and the repro-worker
                # startup sweep) own the cleanup.
                pass
            else:
                # speculation losers still executing at shutdown burned
                # their time just the same — charge what the run observed
                end_t = time.perf_counter()
                for cid, starts in run_started.items():
                    if state.get(cid) == DONE:
                        for st in starts.values():
                            stats["speculative_wasted_s"] += end_t - st
                for w in workers.values():
                    if w.alive:
                        try:
                            w.chan.send(("stop",))
                        except ChannelClosed:
                            pass
                for w in workers.values():
                    if w.proc is not None:
                        w.proc.join(timeout=5.0)
                        if w.proc.is_alive():
                            w.proc.terminate()
                            w.proc.join(timeout=5.0)
                    w.chan.close()
                for sock, _ in dial_stash:      # dials we never adopted
                    try:
                        sock.close()
                    except OSError:
                        pass
                # hygiene sweep: free tracked handles, then clear the run's
                # /dev/shm prefix AND its peer-socket tmpdir — orphans from
                # workers killed mid-publish never cleaned up after
                # themselves.  A resumed run also sweeps every PRIOR
                # incarnation's prefix: their surviving segments were the
                # recovery inputs and are dead weight now the run is over
                if runlog is not None:
                    runlog.close()
                    for p in [seg_prefix] + old_prefixes:
                        serde.clear_resume_lease(p)
                store.release_all()
                serde.sweep_segments(seg_prefix)
                for p in old_prefixes:
                    serde.sweep_segments(p)
                serde.sweep_peer_sockets(peer_dir)
            self.wall_time = time.perf_counter() - t0
            # finalize the replayable trace (benchmarks/hillclimb feed it
            # into the simulator's offline policy search)
            trace.n_workers = len(workers) or self.n_workers
            cost_model.observe_dispatch(
                stats["dispatch_overhead_s"], stats["dispatched"])
            trace.unit_s = cost_model.unit_s or 0.0
            trace.dispatch_s = cost_model.dispatch_s
            stats["cost_unit_s"] = trace.unit_s
            stats["dispatch_cost_s"] = cost_model.dispatch_s

        if error:
            raise error[0]
        if resident:
            return {}       # results flow through each job's future
        if coll_map is None:
            return {t: store.cache[t] for t in required}
        # map lowered values back to the user's tid space (stage nodes are
        # runtime detail; the contract is the traced graph's ids)
        return {t: store.cache[coll_map[t]] for t in user_required}
